"""Optimizer unit/property tests: convergence on quadratics, schedule
shape, int8 moment quantisation, error-feedback compression."""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.train import optimizer as opt_mod


def _quad_target(optname, steps=200, **kw):
    tcfg = TrainConfig(optimizer=optname, lr=0.1, warmup_steps=5,
                       total_steps=steps, weight_decay=0.0, **kw)
    opt = opt_mod.make_optimizer(tcfg)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros((3,))}
    state = opt.init(params)
    loss = lambda p: jnp.sum((p["w"] - target) ** 2)
    for i in range(steps):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params, jnp.asarray(i))
        params = opt_mod.apply_updates(params, upd)
    return float(loss(params))


@pytest.mark.parametrize("optname", ["adamw", "sgdm", "adafactor"])
def test_converges_on_quadratic(optname):
    assert _quad_target(optname) < 0.05


def test_int8_moments_still_converge():
    assert _quad_target("adamw", opt_state_dtype="int8") < 0.2


class TestSchedule:
    def test_warmup_then_decay(self):
        tcfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
        lrs = [float(opt_mod.schedule(tcfg, s)) for s in range(100)]
        assert lrs[0] < lrs[5] < lrs[9]                  # warming up
        assert abs(lrs[9] - 1.0) < 1e-6                  # peak at lr
        assert lrs[50] > lrs[99]                         # cosine decay
        assert lrs[99] >= 0.1 * 1.0 - 1e-6               # 10% floor

    def test_nonzero_at_step0(self):
        tcfg = TrainConfig(lr=1.0, warmup_steps=10, total_steps=100)
        assert float(opt_mod.schedule(tcfg, 0)) > 0.0


class TestCompression:
    def test_ef_error_is_residual(self):
        g = {"w": jnp.linspace(-1, 1, 300)}
        err = opt_mod.ef_compress_init(g)
        out, new_err = opt_mod.ef_compress(g, err)
        np.testing.assert_allclose(
            np.asarray(out["w"] + new_err["w"]), np.asarray(g["w"]),
            atol=1e-6)

    def test_ef_error_feedback_recovers_bias(self):
        # a constant tiny gradient below one quantisation step must
        # eventually be transmitted thanks to error accumulation: after n
        # rounds the total transmitted mass is within one LSB of n*g.
        g = {"w": jnp.full((256,), 1e-4)}
        # include one big element so the int8 scale makes 1e-4 sub-LSB
        g = {"w": g["w"].at[0].set(1.0)}
        err = opt_mod.ef_compress_init(g)
        sent = jnp.zeros((256,))
        n = 200
        for _ in range(n):
            out, err = opt_mod.ef_compress(g, err)
            sent = sent + out["w"]
        lsb = 1.0 / 127
        resid = jnp.abs(sent[1:] - n * 1e-4)
        assert float(jnp.max(resid)) <= lsb + 1e-6
        # and without error feedback nothing would ever be sent:
        out_plain, _ = opt_mod.ef_compress(g, opt_mod.ef_compress_init(g))
        assert float(jnp.max(jnp.abs(out_plain["w"][1:]))) == 0.0

    @hypothesis.given(st.integers(1, 5))
    @hypothesis.settings(max_examples=5, deadline=None)
    def test_q8_roundtrip_bound(self, seed):
        v = jax.random.normal(jax.random.PRNGKey(seed), (512,))
        q, s = opt_mod._q8(v)
        back = opt_mod._dq8_static(q, s, v.shape)
        err = jnp.abs(back - v)
        # per-block absmax scaling bounds error by scale/2 per block
        assert float(jnp.max(err)) <= float(jnp.max(jnp.abs(v))) / 127 + 1e-6


def test_global_norm_clip():
    tree = {"a": jnp.asarray([3.0, 4.0])}
    clipped, norm = opt_mod.clip_by_global_norm(tree, 1.0)
    assert abs(float(norm) - 5.0) < 1e-6
    assert abs(float(jnp.linalg.norm(clipped["a"])) - 1.0) < 1e-5
