"""Traffic lab: arrival processes, continuous batching, mesh serving
(ISSUE 6).

The contracts under test:

  * arrival generation is keyed-deterministic — same ``WorkloadConfig``
    ⇒ bit-identical trace; the MMPP process is measurably burstier than
    Poisson at the same mean rate;
  * admission control never overflows the engine's cache slots, admits
    FIFO within a priority level, *rejects* (never raises on) oversized
    requests, and deadline eviction frees slots mid-run;
  * per-conversion thermal dither is keyed by the conversion-clock step:
    same step ⇒ bitwise-identical conversions, different steps differ,
    ``thermal_sigma_v = 0`` stays bitwise nominal;
  * a SINGLE-device serve mesh decodes bitwise identically to the
    unsharded engine (the sharding acceptance gate);
  * sharded ``collect_stats`` merges per-device observer states exactly.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.tiling import Fleet
from repro.configs.base import MFTechniqueConfig, ModelConfig
from repro.core import cim
from repro.core.cim import CimConfig, cim_mf_matmul
from repro.launch.mesh import make_serve_mesh
from repro.serve.engine import ServeEngine
from repro.silicon import SiliconConfig, projection_silicon, sample_fleet
from repro.traffic import (AdmissionConfig, ContinuousBatcher, VirtualClock,
                           WorkloadConfig, generate, percentile, replay_trace,
                           shard_engine)
from repro.traffic.report import from_run
from repro.traffic.workload import TrafficRequest

CIM = CimConfig(4, 4, 5, 31)


def _cfg(**kw):
    base = dict(
        name="traffic-tiny", family="lm", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
        dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=CIM))
    base.update(kw)
    return ModelConfig(**base)


def _engine(slots=2, max_len=32, fleet=None, **kw):
    from repro.models import transformer as T
    cfg = _cfg()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    return ServeEngine(params, cfg, slots=slots, max_len=max_len,
                       fleet=fleet, **kw)


def _req(rid, t, prompt, n_new, ttft_dl, dl, priority=0):
    return TrafficRequest(rid=rid, t_arrival_s=t, prompt=prompt,
                          max_new_tokens=n_new, ttft_deadline_s=ttft_dl,
                          deadline_s=dl, priority=priority)


class TestArrivalDeterminism:
    @pytest.mark.parametrize("process", ["poisson", "mmpp"])
    def test_same_seed_same_trace(self, process):
        cfg = WorkloadConfig(process=process, n_requests=32, seed=7)
        a, b = generate(cfg), generate(cfg)
        assert len(a) == len(b) == 32
        for ra, rb in zip(a, b):
            assert ra.t_arrival_s == rb.t_arrival_s
            assert ra.prompt == rb.prompt
            assert ra.max_new_tokens == rb.max_new_tokens
            assert ra.ttft_deadline_s == rb.ttft_deadline_s
            assert ra.deadline_s == rb.deadline_s
            assert ra.priority == rb.priority

    def test_different_seed_differs(self):
        a = generate(WorkloadConfig(n_requests=16, seed=0))
        b = generate(WorkloadConfig(n_requests=16, seed=1))
        assert [r.t_arrival_s for r in a] != [r.t_arrival_s for r in b]

    def test_processes_differ(self):
        a = generate(WorkloadConfig(n_requests=16, seed=0))
        b = generate(WorkloadConfig(n_requests=16, seed=0,
                                    process="mmpp"))
        assert [r.t_arrival_s for r in a] != [r.t_arrival_s for r in b]

    def test_mmpp_burstier_than_poisson(self):
        # Same mean rate; the MMPP inter-arrival coefficient of variation
        # must exceed the (≈1) Poisson one. Deterministic given the seed.
        def cv(reqs):
            dt = np.diff([r.t_arrival_s for r in reqs])
            return dt.std() / dt.mean()
        n = 512
        po = generate(WorkloadConfig(n_requests=n, seed=3))
        mm = generate(WorkloadConfig(n_requests=n, seed=3, process="mmpp",
                                     burst_rate_mult=8.0,
                                     burst_fraction=0.2))
        assert cv(mm) > 1.2 * cv(po)

    def test_mmpp_mean_rate_normalised(self):
        cfg = WorkloadConfig(n_requests=2048, seed=5, process="mmpp",
                             rate_rps=4.0, burst_rate_mult=6.0,
                             burst_fraction=0.3)
        reqs = generate(cfg)
        rate = (len(reqs) - 1) / (reqs[-1].t_arrival_s
                                  - reqs[0].t_arrival_s)
        assert abs(rate - cfg.rate_rps) / cfg.rate_rps < 0.25

    def test_deadlines_are_absolute(self):
        cfg = WorkloadConfig(n_requests=8, seed=2, ttft_slo_s=0.3,
                             tpot_slo_s=0.05)
        for r in generate(cfg):
            assert r.ttft_deadline_s == pytest.approx(
                r.t_arrival_s + 0.3)
            assert r.deadline_s == pytest.approx(
                r.ttft_deadline_s + 0.05 * r.max_new_tokens)
            assert 1 <= len(r.prompt)
            assert all(1 <= t < cfg.vocab_size for t in r.prompt)

    def test_config_validation(self):
        with pytest.raises(ValueError, match="degenerate"):
            WorkloadConfig(rate_rps=0.0)
        with pytest.raises(ValueError, match="unknown arrival process"):
            WorkloadConfig(process="pareto")
        with pytest.raises(ValueError, match="burst_fraction"):
            WorkloadConfig(process="mmpp", burst_fraction=1.5)

    def test_replay_trace(self):
        reqs = replay_trace([0.0, 0.5, 1.25], [[1, 2], [3], [4, 5, 6]],
                            [4, 2, 8], ttft_slo_s=0.2, tpot_slo_s=0.1,
                            priorities=[1, 0, 1])
        assert [r.t_arrival_s for r in reqs] == [0.0, 0.5, 1.25]
        assert reqs[2].deadline_s == pytest.approx(1.25 + 0.2 + 0.8)
        assert [r.priority for r in reqs] == [1, 0, 1]
        with pytest.raises(ValueError, match="sorted"):
            replay_trace([1.0, 0.5], [[1], [2]], [1, 1])
        with pytest.raises(ValueError, match="columns disagree"):
            replay_trace([0.0], [[1], [2]], [1])


class TestClockAndPercentile:
    def test_percentile_known_values(self):
        xs = [5.0, 1.0, 3.0, 2.0, 4.0]
        assert percentile(xs, 0) == 1.0
        assert percentile(xs, 50) == 3.0
        assert percentile(xs, 100) == 5.0
        assert percentile(xs, 25) == 2.0
        assert percentile([7.0], 99) == 7.0
        assert np.isnan(percentile([], 50))
        with pytest.raises(ValueError, match="outside"):
            percentile(xs, 101)

    def test_small_sample_tail_percentiles(self):
        # Hyndman-Fan type 7 (the documented method): with n samples the
        # tail sits at fractional rank (n-1)*q/100 BETWEEN the two
        # largest order statistics — never extrapolated past the max.
        xs = [1.0, 2.0, 3.0, 4.0]
        assert percentile(xs, 99) == pytest.approx(3.97)
        assert percentile(xs, 99.9) == pytest.approx(3.997)
        assert percentile(xs, 99) <= max(xs)
        # Adding one large sample moves the tail deterministically: the
        # p99 rank (4*0.99 = 3.96) now interpolates into the new max.
        assert percentile(xs + [40.0], 99) == pytest.approx(
            4.0 + 0.96 * 36.0)
        # Degenerate sizes: n=1 clamps to the sample, n=2 interpolates.
        assert percentile([7.0], 99.9) == 7.0
        assert percentile([1.0, 2.0], 99) == pytest.approx(1.99)

    def test_virtual_clock(self):
        c = VirtualClock(0.25, prefill_s=1.0)
        c.on_decode()
        c.on_prefill()
        assert c.now == pytest.approx(1.25)
        c.fast_forward(0.5)         # never backwards
        assert c.now == pytest.approx(1.25)
        c.fast_forward(3.0)
        assert c.now == pytest.approx(3.0)
        assert VirtualClock(0.1).prefill_s == 0.1
        with pytest.raises(ValueError, match="tick_s"):
            VirtualClock(0.0)


class TestAdmissionInvariants:
    def test_no_slot_overflow_and_fifo(self):
        eng = _engine(slots=2, max_len=32)
        # 8 simultaneous arrivals against 2 slots: queue must drain
        # strictly FIFO and in-flight never exceeds the slot count.
        reqs = [_req(i, 0.0, [1 + i, 2 + i], 4, 1e9, 1e9)
                for i in range(8)]
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.01))
        log = bat.run(reqs)
        assert all(r.state == "completed" for r in reqs)
        assert max(log.occupied) <= eng.slots
        assert not eng.occupied_slots
        admits = [r.t_admit_s for r in reqs]
        assert admits == sorted(admits)      # FIFO by rid at equal t

    def test_priority_admitted_first(self):
        eng = _engine(slots=1, max_len=32)
        reqs = [_req(0, 0.0, [3], 2, 1e9, 1e9, priority=1),
                _req(1, 0.0, [4], 2, 1e9, 1e9, priority=1),
                _req(2, 0.0, [5], 2, 1e9, 1e9, priority=0),
                _req(3, 0.0, [6], 2, 1e9, 1e9, priority=0)]
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.01))
        bat.run(reqs)
        assert all(r.state == "completed" for r in reqs)
        lo = max(reqs[2].t_admit_s, reqs[3].t_admit_s)
        hi = min(reqs[0].t_admit_s, reqs[1].t_admit_s)
        assert lo < hi        # both priority-0 served before priority-1

    def test_oversized_request_rejected_not_raised(self):
        eng = _engine(slots=2, max_len=16)
        reqs = [_req(0, 0.0, [1] * 30, 4, 1e9, 1e9),   # prompt > cache
                _req(1, 0.0, [2, 3], 30, 1e9, 1e9),    # decode > cache
                _req(2, 0.0, [4, 5], 4, 1e9, 1e9)]
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.01))
        bat.run(reqs)
        assert reqs[0].state == "rejected"
        assert reqs[1].state == "rejected"
        assert reqs[2].state == "completed"

    def test_queue_overflow_sheds(self):
        eng = _engine(slots=1, max_len=32)
        reqs = [_req(i, 0.0, [1 + i], 2, 1e9, 1e9) for i in range(6)]
        bat = ContinuousBatcher(
            eng, clock=VirtualClock(0.01),
            admission=AdmissionConfig(max_queue=2))
        bat.run(reqs)
        states = [r.state for r in reqs]
        # All 6 arrive in one pull: exactly max_queue of them fit the
        # queue, the rest shed at admission.
        assert states.count("completed") == 2
        assert states.count("rejected") == 4
        assert [r.rid for r in reqs if r.state == "completed"] == [0, 1]

    def test_deadline_eviction_frees_slot(self):
        eng = _engine(slots=1, max_len=64)
        # A can never finish by its deadline (20 ticks x 0.1 s against a
        # 1.2 s completion budget): it must be EVICTED, and B — arriving
        # after the eviction point — must then complete in the freed slot.
        a = _req(0, 0.0, [7], 20, ttft_dl=1.0, dl=1.2)
        b = _req(1, 2.0, [8], 2, ttft_dl=1e9, dl=1e9)
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.1))
        bat.run([a, b])
        assert a.state == "evicted" and a.t_done_s < 2.0
        assert b.state == "completed" and not a.slo_met and b.slo_met
        assert not eng.occupied_slots

    def test_eviction_surfaces_freed_token_count(self):
        from repro import obs
        eng = _engine(slots=1, max_len=64)
        a = _req(0, 0.0, [7], 20, ttft_dl=1.0, dl=1.2)
        b = _req(1, 2.0, [8], 2, ttft_dl=1e9, dl=1e9)
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.1))
        with obs.tracing() as buf:
            rep = from_run(bat.run([a, b]), eng)
        # The deadline eviction threw away A's in-flight decode output;
        # that count must flow engine counter -> report -> trace event.
        freed = len(a.serve.out)
        assert a.state == "evicted" and freed > 0
        assert rep.evicted == 1
        assert rep.evicted_tokens == freed
        assert eng.counters()["evicted_tokens"] == freed
        (ev,) = buf.by_kind("evict")
        assert ev.data["tokens"] == freed
        assert rep.to_json()["evicted_tokens"] == freed

    def test_drop_late_sheds_queued_past_ttft(self):
        def run(drop):
            eng = _engine(slots=1, max_len=64)
            blocker = _req(0, 0.0, [3], 30, 1e9, 1e9)
            late = _req(1, 0.0, [4], 2, ttft_dl=0.05, dl=1e9)
            bat = ContinuousBatcher(
                eng, clock=VirtualClock(0.1),
                admission=AdmissionConfig(drop_late=drop))
            bat.run([blocker, late])
            return late
        assert run(True).state == "rejected"
        kept = run(False)
        assert kept.state == "completed" and not kept.slo_met

    def test_out_of_ticks_drains_terminal(self):
        eng = _engine(slots=1, max_len=32)
        reqs = [_req(i, 0.0, [1 + i], 8, 1e9, 1e9) for i in range(4)]
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.01))
        log = bat.run(reqs, max_ticks=3)
        assert log.out_of_ticks
        assert all(r.state in ("completed", "rejected", "evicted")
                   for r in reqs)
        assert not eng.occupied_slots    # drain really freed the slots

    def test_report_roll_up(self):
        import json
        fleet = Fleet(n_macros=4096, cfg=CIM)
        eng = _engine(slots=2, max_len=32, fleet=fleet)
        reqs = generate(WorkloadConfig(
            rate_rps=50.0, n_requests=10, seed=1, prompt_len_max=6,
            decode_len_max=6, vocab_size=64, ttft_slo_s=1e6,
            tpot_slo_s=1e6))
        bat = ContinuousBatcher(eng, clock=VirtualClock(0.02))
        rep = from_run(bat.run(reqs), eng)
        assert rep.completed == 10 and rep.slo_attainment == 1.0
        assert rep.completed + rep.rejected + rep.evicted == 10
        assert rep.tok_s > 0 and rep.decode_tokens > 0
        assert rep.latency_p50_s <= rep.latency_p99_s
        assert 0.0 < rep.slot_utilization <= 1.0
        assert rep.wave is not None and rep.energy_per_token_j > 0
        json.dumps(rep.to_json())        # artifact-safe payload


THERMAL = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0,
                        thermal_sigma_v=0.004)


class TestThermalDither:
    def _sil(self, scfg, k=70, n=9):
        fleet = sample_fleet(jax.random.PRNGKey(5), 24, 31, scfg)
        return projection_silicon(fleet, scfg, k, n)

    def _y(self, sil, step):
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 70))
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        cfg = CimConfig(8, 8, 5, 31)
        with cim.conversion_clock(step):
            return np.asarray(cim_mf_matmul(x, w, cfg, silicon=sil))

    def test_same_step_bitwise_identical(self):
        sil = self._sil(THERMAL)
        np.testing.assert_array_equal(self._y(sil, 5), self._y(sil, 5))

    def test_steps_decorrelate(self):
        sil = self._sil(THERMAL)
        assert not np.array_equal(self._y(sil, 0), self._y(sil, 1))

    def test_sigma0_thermal_is_bitwise_nominal(self):
        quiet = SiliconConfig(cap_sigma=0.0, comparator_sigma_v=0.0)
        assert quiet.is_nominal and not THERMAL.is_nominal
        sil = self._sil(quiet)
        assert sil.thermal_fs is None
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 70))
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        cfg = CimConfig(8, 8, 5, 31)
        np.testing.assert_array_equal(
            np.asarray(cim_mf_matmul(x, w, cfg)), self._y(sil, 3))

    def test_thermal_serving_is_reproducible(self):
        # The engine threads its stream counter into the jitted step, so
        # two identical engines replay the same dither sequence.
        fleet = Fleet(n_macros=4096, cfg=CIM)
        outs = []
        for _ in range(2):
            eng = _engine(slots=2, max_len=32, fleet=fleet,
                          silicon=THERMAL)
            reqs = [_req(i, 0.0, [5 + i, 6 + i], 6, 1e9, 1e9)
                    for i in range(4)]
            ContinuousBatcher(eng, clock=VirtualClock(0.01)).run(reqs)
            outs.append([r.serve.out for r in reqs])
        assert outs[0] == outs[1]


class TestMeshServing:
    def test_make_serve_mesh_rejects_wrong_device_count(self):
        with pytest.raises(ValueError):
            make_serve_mesh(data=2, fleet=2,
                            devices=list(jax.devices())[:1])

    def test_single_device_mesh_bitwise_parity(self):
        # THE sharding acceptance gate: a (1, 1) serve mesh must decode
        # bitwise identically to the unsharded engine.
        fleet = Fleet(n_macros=4096, cfg=CIM)
        outs = []
        for shard in (False, True):
            eng = _engine(slots=2, max_len=32, fleet=fleet)
            if shard:
                info = shard_engine(eng, make_serve_mesh(
                    data=1, fleet=1, devices=jax.devices()[:1]))
                assert info["data"] == 1 and info["fleet"] == 1
            reqs = [_req(i, 0.0, [1 + i, 2 + i, 3 + i], 6, 1e9, 1e9)
                    for i in range(5)]
            ContinuousBatcher(eng, clock=VirtualClock(0.01)).run(reqs)
            outs.append([r.serve.out for r in reqs])
        assert outs[0] == outs[1]


MULTIDEV_TRAFFIC_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys
    sys.path.insert(0, "src")
    import jax, jax.numpy as jnp, numpy as np
    from repro.compiler.tiling import Fleet
    from repro.configs.base import MFTechniqueConfig, ModelConfig
    from repro.core.cim import CimConfig
    from repro.launch.mesh import make_serve_mesh
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    from repro.traffic import shard_engine
    from repro.traffic.workload import TrafficRequest

    CIM = CimConfig(4, 4, 5, 31)
    cfg = ModelConfig(name="t", family="lm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                      dtype=jnp.float32,
                      mf=MFTechniqueConfig(mode="cim_sim", cim=CIM))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    fleet = Fleet(n_macros=4096, cfg=CIM)

    def mkreqs():
        from repro.serve.engine import Request
        return [Request(prompt=[1 + i, 2 + i, 3 + i], max_new_tokens=6)
                for i in range(8)]

    outs = []
    infos = []
    for mesh_kw in (None, dict(data=4, fleet=1), dict(data=2, fleet=2)):
        eng = ServeEngine(params, cfg, slots=4, max_len=32, fleet=fleet)
        if mesh_kw is not None:
            infos.append(shard_engine(eng, make_serve_mesh(**mesh_kw)))
        outs.append([r.out for r in eng.run(mkreqs())])
    assert outs[0] == outs[1], "data=4 mesh decode diverged"
    assert outs[0] == outs[2], "data=2 x fleet=2 mesh decode diverged"
    assert infos[0]["cache_sharded_leaves"] > 0
    assert infos[1]["param_sharded_leaves"] > 0
    # ragged slot split must refuse, not silently replicate
    eng = ServeEngine(params, cfg, slots=3, max_len=32, fleet=fleet)
    try:
        shard_engine(eng, make_serve_mesh(data=4, fleet=1))
    except ValueError:
        pass
    else:
        raise AssertionError("ragged slot split did not raise")
    print("MULTIDEV_TRAFFIC_OK")
""")


@pytest.mark.slow
def test_traffic_multidevice_subprocess():
    """Sharded serving on a real 4-device host mesh is bitwise equal to
    the unsharded engine (subprocess so the fake device count doesn't
    leak into this test session)."""
    r = subprocess.run([sys.executable, "-c", MULTIDEV_TRAFFIC_SCRIPT],
                       capture_output=True, text=True, timeout=540,
                       cwd=os.path.dirname(os.path.dirname(
                           os.path.abspath(__file__))))
    assert "MULTIDEV_TRAFFIC_OK" in r.stdout, r.stdout + r.stderr


class TestShardedCollectStats:
    def test_duplicate_device_shards_merge_exactly(self):
        from repro.calib import observers as obs
        from repro.calib.corpus import attach_observer_ids, collect_stats
        from repro.models import transformer as T
        cfg = _cfg(mf=MFTechniqueConfig(mode="mf"))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, registry = attach_observer_ids(params)
        fwd = lambda p, b: T.lm_forward(p, b, cfg)[0]
        batches = [{"tokens": jax.random.randint(jax.random.PRNGKey(i),
                                                 (4, 8), 0, 64)}
                   for i in (1, 2)]
        ocfg = obs.ObserverConfig()
        c0 = collect_stats(fwd, tagged, batches, registry, ocfg)
        dev = jax.devices()[0]
        # Duplicate device list: exercises the shard/dispatch/merge path
        # on a single-device host; a 3-way split of a 4-row batch also
        # covers uneven block sizes.
        c3 = collect_stats(fwd, tagged, batches, registry, ocfg,
                           devices=[dev, dev, dev])
        np.testing.assert_array_equal(c0.count, c3.count)
        np.testing.assert_array_equal(c0.amax, c3.amax)
        np.testing.assert_array_equal(c0.hist, c3.hist)

    def test_more_devices_than_rows_skips_empty_shards(self):
        from repro.calib import observers as obs
        from repro.calib.corpus import attach_observer_ids, collect_stats
        from repro.models import transformer as T
        cfg = _cfg(mf=MFTechniqueConfig(mode="mf"))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, registry = attach_observer_ids(params)
        fwd = lambda p, b: T.lm_forward(p, b, cfg)[0]
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(9),
                                              (1, 8), 0, 64)}
        ocfg = obs.ObserverConfig()
        c0 = collect_stats(fwd, tagged, [batch], registry, ocfg)
        dev = jax.devices()[0]
        c4 = collect_stats(fwd, tagged, [batch], registry, ocfg,
                           devices=[dev] * 4)
        np.testing.assert_array_equal(c0.count, c4.count)
        np.testing.assert_array_equal(c0.hist, c4.hist)
        with pytest.raises(ValueError, match="non-empty"):
            collect_stats(fwd, tagged, [batch], registry, ocfg,
                          devices=[])
