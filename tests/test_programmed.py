"""Weight-stationary programmed runtime: bit-exact parity vs on-the-fly.

The contract under test (ISSUE 2): for the same ``CimConfig`` and the same
activation scale, the programmed path — plane-level, lossless-collapsed,
Pallas-kernel, and compiler-tiled — is bit-identical to the existing
on-the-fly path.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import quant
from repro.core.cim import CimConfig, cim_mf_matmul
from repro.core.programmed import (DEFAULT_ACT_AMAX, adc_exactly_lossless,
                                   cim_mf_matmul_programmed, default_static_sx,
                                   program_macro, program_weights,
                                   strip_programmed)

# Both paper design points (8x62 -> 5-bit, 8x30 -> 4-bit).
DESIGNS = [(31, 5), (15, 4)]
BITS = [2, 4, 8]


def _xw(b=3, k=70, n=9):
    x = jax.random.normal(jax.random.PRNGKey(0), (b, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    return x, w


def _parity(x, w, cfg, **program_kw):
    sx = quant.calibrate_scale(x.reshape(-1, x.shape[-1]), cfg.x_bits)
    prog = program_macro(w, cfg, sx=sx, **program_kw)
    y0 = np.asarray(cim_mf_matmul(x, w, cfg))
    y1 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg))
    np.testing.assert_array_equal(y0, y1)


class TestMonolithicParity:
    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("wb", BITS)
    @pytest.mark.parametrize("xb", BITS)
    def test_einsum_path_bit_exact(self, wb, xb, m, a):
        x, w = _xw()
        # prefer_lossless=False exercises the plane-level programmed path
        # even at the exactly-lossless design points.
        _parity(x, w, CimConfig(wb, xb, a, m), prefer_lossless=False)

    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("wb", BITS)
    @pytest.mark.parametrize("xb", BITS)
    def test_lossless_collapse_bit_exact(self, wb, xb, m, a):
        assert adc_exactly_lossless(CimConfig(wb, xb, a, m))
        x, w = _xw()
        _parity(x, w, CimConfig(wb, xb, a, m), prefer_lossless=True)

    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("wb,xb", [(2, 2), (8, 8), (2, 8), (8, 4)])
    def test_kernel_path_bit_exact(self, wb, xb, m, a):
        x, w = _xw(k=2 * m + 9, n=7)
        _parity(x, w, CimConfig(wb, xb, a, m, use_kernel=True))

    def test_non_lossless_point_falls_back_to_planes(self):
        cfg = CimConfig(8, 8, 4, 31)   # 2^4-1 = 15 != 31 columns
        assert not adc_exactly_lossless(cfg)
        x, w = _xw()
        sx = quant.calibrate_scale(x, 8)
        prog = program_macro(w, cfg, sx=sx)
        assert prog.lossless is None and prog.state is not None
        _parity(x, w, cfg)

    def test_batched_leading_dims(self):
        cfg = CimConfig(8, 8, 5, 31)
        x = jax.random.normal(jax.random.PRNGKey(2), (2, 3, 45))
        w = jax.random.normal(jax.random.PRNGKey(3), (45, 5))
        sx = quant.calibrate_scale(x.reshape(-1, 45), 8)
        prog = program_macro(w, cfg, sx=sx)
        y0 = np.asarray(cim_mf_matmul(x, w, cfg))
        y1 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg))
        assert y1.shape == (2, 3, 5)
        np.testing.assert_array_equal(y0, y1)

    def test_variability_injection_bit_exact_on_plane_path(self):
        from repro.core import (VariabilityConfig, sample_cap_weights,
                                sample_comparator_offset)
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=62)
        var = VariabilityConfig(cap_sigma=0.12)
        caps = sample_cap_weights(jax.random.PRNGKey(7), 62, var)
        off = sample_comparator_offset(jax.random.PRNGKey(8), var)
        sx = quant.calibrate_scale(x, 8)
        prog = program_macro(w, cfg, sx=sx, prefer_lossless=False)
        y0 = np.asarray(cim_mf_matmul(x, w, cfg, cap_weights=caps,
                                      comparator_offset=off))
        y1 = np.asarray(cim_mf_matmul_programmed(x, prog, cfg,
                                                 cap_weights=caps,
                                                 comparator_offset=off))
        np.testing.assert_array_equal(y0, y1)

    def test_variability_rejected_on_collapsed_state(self):
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw()
        prog = program_macro(w, cfg, sx=0.1)
        with pytest.raises(ValueError, match="variability"):
            cim_mf_matmul_programmed(x, prog, cfg,
                                     cap_weights=jnp.ones((70,)))


class TestTiledParity:
    @pytest.mark.parametrize("m,a", DESIGNS)
    @pytest.mark.parametrize("wb,xb", [(2, 2), (4, 8), (8, 8)])
    def test_compiler_tiled_bit_exact(self, wb, xb, m, a):
        from repro.compiler.execute import (compiled_matmul,
                                            compiled_matmul_programmed,
                                            program_layer_tiles)
        from repro.compiler.tiling import plan_tiling
        cfg = CimConfig(wb, xb, a, m)
        x, w = _xw(k=3 * m + 7, n=21)
        plan = plan_tiling(w.shape[0], w.shape[1], cfg, tile_k_chunks=2,
                           tile_n=8)
        sx = quant.calibrate_scale(x, cfg.x_bits)
        prog = program_layer_tiles(w, plan, cfg, sx=sx)
        mono = np.asarray(cim_mf_matmul(x, w, cfg))
        tiled = np.asarray(compiled_matmul(x, w, plan, cfg))
        ptiled = np.asarray(compiled_matmul_programmed(x, prog, plan, cfg))
        np.testing.assert_array_equal(mono, tiled)
        np.testing.assert_array_equal(mono, ptiled)

    def test_plan_mismatch_rejected(self):
        from repro.compiler.execute import (compiled_matmul_programmed,
                                            program_layer_tiles)
        from repro.compiler.tiling import plan_tiling
        cfg = CimConfig(8, 8, 5, 31)
        x, w = _xw(k=70, n=12)
        plan = plan_tiling(70, 12, cfg, tile_k_chunks=1, tile_n=4)
        prog = program_layer_tiles(w, plan, cfg, sx=0.1)
        other = plan_tiling(70, 12, cfg, tile_k_chunks=2, tile_n=4)
        with pytest.raises(ValueError, match="slicing"):
            compiled_matmul_programmed(x, prog, other, cfg)


class TestModelProgramming:
    def _cfg(self, use_kernel=False):
        from repro.configs.base import MFTechniqueConfig, ModelConfig
        return ModelConfig(
            name="prog-tiny", family="lm", n_layers=3, d_model=32, n_heads=4,
            n_kv_heads=2, d_ff=64, vocab_size=64, dtype=jnp.float32,
            mf=MFTechniqueConfig(mode="cim_sim",
                                 cim=CimConfig(4, 4, 5, 31,
                                               use_kernel=use_kernel)))

    def test_program_weights_round_trip_and_decode(self):
        from repro.models import transformer as T
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        pp = program_weights(params, cfg.mf.cim)
        # every MF projection gained a prog entry; stripping restores the
        # original tree structure
        assert jax.tree.structure(strip_programmed(pp)) == \
            jax.tree.structure(params)
        cache = T.lm_init_cache(cfg, 2, 8)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        step = jax.jit(lambda p, c, t: T.lm_decode_step(p, c, t, cfg))
        logits, _ = step(pp, cache, jnp.array([1, 2]))
        assert logits.shape == (2, 64)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_programmed_decode_matches_explicit_static_scale(self):
        # The embedded programmed state must be what apply_projection uses:
        # decoding twice from independently programmed trees is identical.
        from repro.models import transformer as T
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        step = jax.jit(lambda p, c, t: T.lm_decode_step(p, c, t, cfg))
        outs = []
        for _ in range(2):
            pp = program_weights(params, cfg.mf.cim)
            cache = T.lm_init_cache(cfg, 2, 8)
            logits, _ = step(pp, cache, jnp.array([3, 4]))
            outs.append(np.asarray(logits))
        np.testing.assert_array_equal(outs[0], outs[1])

    def test_apply_projection_consumes_embedded_prog(self):
        from repro.core.mf import ExecMode, apply_projection
        cfg = CimConfig(8, 8, 5, 31)
        w = jax.random.normal(jax.random.PRNGKey(1), (40, 6))
        p = {"w": w, "alpha": jnp.ones((6,))}
        pp = program_weights({"proj": p}, cfg)["proj"]
        assert "prog" in pp
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 40))
        via_params = apply_projection(pp, x, ExecMode.CIM_SIM, cim_cfg=cfg)
        direct = cim_mf_matmul_programmed(x, pp["prog"], cfg) * pp["alpha"]
        np.testing.assert_array_equal(np.asarray(via_params),
                                      np.asarray(direct))

    def test_default_static_sx(self):
        cfg = CimConfig(8, 8, 5, 31)
        assert default_static_sx(cfg) == DEFAULT_ACT_AMAX / 127.0


class TestKernelOpsPacking:
    def test_pack_chunks_precondition_is_clear_error(self):
        from repro.kernels.ops import pack_chunks
        with pytest.raises(ValueError, match="CHUNK_PAD"):
            pack_chunks(jnp.ones((2, 64)), 33)
        with pytest.raises(ValueError, match=">= 1"):
            pack_chunks(jnp.ones((2, 64)), 0)

    def test_cim_mav_packed_matches_unpacked(self):
        from repro.kernels.ops import cim_mav, cim_mav_packed, pack_chunks, \
            pack_planes
        m, a = 31, 5
        gates = (jax.random.uniform(jax.random.PRNGKey(0), (3, 70)) > 0.5
                 ).astype(jnp.float32)
        planes = (jax.random.uniform(jax.random.PRNGKey(1), (7, 70, 9)) > 0.5
                  ).astype(jnp.float32)
        y0 = cim_mav(gates, planes, m_columns=m, adc_bits=a)
        y1 = cim_mav_packed(pack_chunks(gates, m), pack_planes(planes, m),
                            m_columns=m, adc_bits=a)
        np.testing.assert_array_equal(np.asarray(y0), np.asarray(y1))


class TestServeEngine:
    def _cfg(self):
        from repro.configs.base import MFTechniqueConfig, ModelConfig
        return ModelConfig(
            name="serve-tiny", family="lm", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
            dtype=jnp.float32,
            mf=MFTechniqueConfig(mode="cim_sim", cim=CimConfig(4, 4, 5, 31)))

    def test_engine_programs_cim_model_and_serves(self):
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=2, max_len=16)
        assert eng.programmed
        done = eng.run([Request(prompt=[1, 2], max_new_tokens=3)
                        for _ in range(3)])
        assert len(done) == 3
        assert all(len(r.out) == 3 and not r.timed_out for r in done)

    def test_engine_program_flag_off_keeps_legacy_path(self):
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=1, max_len=8, program=False)
        assert not eng.programmed and eng._exec_params is params

    def test_run_returns_inflight_and_unscheduled_on_timeout(self):
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine
        cfg = dataclasses.replace(self._cfg(), mf=dataclasses.replace(
            self._cfg().mf, enabled=False))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=1, max_len=64)
        reqs = [Request(prompt=[1], max_new_tokens=50),
                Request(prompt=[2], max_new_tokens=50)]
        done = eng.run(reqs, max_ticks=3)
        # nothing is silently dropped: both come back, marked timed_out
        assert len(done) == 2
        assert all(r.timed_out for r in done)
        assert len(done[0].out) == 3          # partial output preserved
        assert eng.free_slots == [0]          # slot released for reuse

    def test_reset_slot_zeroes_only_target_slot(self):
        from repro.models import transformer as T
        from repro.serve.engine import _reset_slot
        cfg = self._cfg()
        cache = T.lm_init_cache(cfg, 3, 8)
        cache = jax.tree.map(
            lambda v: v + 5 if v.dtype == jnp.int32 else v, cache)
        out = _reset_slot(cache, 1)
        pos = np.asarray(out["pos"])
        np.testing.assert_array_equal(pos, [5, 0, 5])


class TestBitPackedState:
    """ISSUE 3 satellite: plane-level programmed state packs 8 bitplane
    cells per byte (sign gate in bit 7); the lossless collapse packs
    magnitude+sign into one byte per (K, N) cell. Unpacking is exact."""

    def test_pack_unpack_round_trip(self):
        from repro.core.cim import cim_program_weight_state
        from repro.core.programmed import (pack_weight_state,
                                           unpack_weight_state)
        cfg = CimConfig(8, 8, 5, 31)
        w = jax.random.normal(jax.random.PRNGKey(0), (70, 9))
        sw = quant.calibrate_scale(w, cfg.w_bits)
        ws = cim_program_weight_state(w, cfg, sw)
        packed = pack_weight_state(ws, cfg)
        assert packed.packed.dtype == jnp.uint8
        back = unpack_weight_state(packed, cfg)
        np.testing.assert_array_equal(np.asarray(ws.wt),
                                      np.asarray(back.wt))
        np.testing.assert_array_equal(np.asarray(ws.gwt),
                                      np.asarray(back.gwt))
        np.testing.assert_array_equal(np.asarray(ws.r_w),
                                      np.asarray(back.r_w))

    def test_plane_state_bytes_drop_8x(self):
        from repro.core.programmed import (programmed_bytes,
                                           programmed_bytes_unpacked)
        cfg = CimConfig(8, 8, 4, 31)    # non-lossless -> plane-level state
        w = jax.random.normal(jax.random.PRNGKey(0), (124, 16))
        p = {"w": w, "alpha": jnp.ones((16,))}
        pp = program_weights({"proj": p}, cfg)
        prog = pp["proj"]["prog"]
        assert prog.state is not None and prog.lossless is None
        packed = programmed_bytes(pp)
        unpacked = programmed_bytes_unpacked(pp, cfg)
        # cell tensors shrink exactly (w_planes + 1)x = 8x at W_P=8; the
        # small f32 residues dilute the whole-state ratio slightly.
        cells = prog.state.packed.size
        assert unpacked - packed == cells * (cfg.w_planes + 1) - cells
        assert unpacked / packed > 6.0

    def test_lossless_state_bytes_drop_2x(self):
        from repro.core.programmed import (programmed_bytes,
                                           programmed_bytes_unpacked)
        cfg = CimConfig(8, 8, 5, 31)    # lossless collapse
        w = jax.random.normal(jax.random.PRNGKey(0), (124, 16))
        p = {"w": w, "alpha": jnp.ones((16,))}
        pp = program_weights({"proj": p}, cfg)
        prog = pp["proj"]["prog"]
        assert prog.lossless is not None
        assert prog.lossless.packed.dtype == jnp.uint8
        assert (programmed_bytes_unpacked(pp, cfg) - programmed_bytes(pp)
                == prog.lossless.packed.size)

    def test_packed_magnitudes_and_gates_recover(self):
        from repro.core.cim import _weight_operands
        cfg = CimConfig(8, 8, 5, 31)
        w = jax.random.normal(jax.random.PRNGKey(1), (45, 7))
        sw = quant.calibrate_scale(w, cfg.w_bits)
        step_w, abs_w, _ = _weight_operands(w, cfg, sw)
        prog = program_macro(w, cfg, sx=0.02)
        np.testing.assert_array_equal(
            np.asarray(prog.lossless.magnitudes()),
            np.asarray(abs_w).astype(np.float32))
        np.testing.assert_array_equal(
            np.asarray(prog.lossless.gates()),
            np.asarray(step_w).astype(np.float32))

    def test_w_bits_over_8_rejected(self):
        cfg = CimConfig(9, 8, 5, 31)
        w = jax.random.normal(jax.random.PRNGKey(0), (40, 4))
        with pytest.raises(ValueError, match="w_bits"):
            program_macro(w, cfg, sx=0.02)
