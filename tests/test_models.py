"""Per-architecture smoke tests: reduced config of the same family, one
forward/train step on CPU, asserting output shapes + finiteness — the
assignment's required smoke for each of the 10 archs."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import MFTechniqueConfig, ParallelConfig, TrainConfig
from repro.configs.registry import ARCH_IDS, get_config
from repro.data.synthetic import DataConfig, lm_batch
from repro.models import encdec as E
from repro.models import transformer as T
from repro.train import train_loop as TL

BATCH, SEQ = 2, 24


def _batch(cfg, seed=0):
    b = {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                      (BATCH, SEQ), 0, cfg.vocab_size),
         "targets": jax.random.randint(jax.random.PRNGKey(seed + 1),
                                       (BATCH, SEQ), 0, cfg.vocab_size)}
    if cfg.vision_tokens:
        b["vision_embeds"] = jax.random.normal(
            jax.random.PRNGKey(seed + 2),
            (BATCH, cfg.vision_tokens, cfg.vision_embed_dim), cfg.dtype)
    if cfg.family == "encdec":
        b = {"frames": jax.random.normal(jax.random.PRNGKey(seed + 3),
                                         (BATCH, SEQ, cfg.d_model),
                                         cfg.dtype),
             "tokens": b["tokens"], "targets": b["targets"]}
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_config(arch, smoke=True)
    tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=4)
    state = TL.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    batch = _batch(cfg)

    if cfg.family == "encdec":
        logits = E.decode_train(
            state.params, E.encode(state.params, batch["frames"], cfg),
            batch["tokens"], cfg)
        assert logits.shape == (BATCH, SEQ, cfg.vocab_size)
    else:
        logits, _ = T.lm_forward(state.params, batch, cfg)
        exp_t = SEQ + (cfg.vision_tokens or 0)
        assert logits.shape == (BATCH, exp_t, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # repro-lint: disable=R003 reason=one-shot test body wrapper
    step = jax.jit(TL.make_train_step(cfg, ParallelConfig(remat="none"),
                                      tcfg))
    state2, metrics = step(state, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert int(state2.step) == 1
    # params actually changed
    changed = any(
        not np.allclose(np.asarray(a, np.float32),
                        np.asarray(b, np.float32))
        for a, b in zip(jax.tree.leaves(state.params),
                        jax.tree.leaves(state2.params)))
    assert changed


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_config(arch, smoke=True)
    if cfg.family == "encdec":
        params = E.encdec_init(jax.random.PRNGKey(0), cfg)
        frames = jax.random.normal(jax.random.PRNGKey(1),
                                   (BATCH, 12, cfg.d_model), cfg.dtype)
        enc_out = E.encode(params, frames, cfg)
        cache = E.encdec_init_cache(cfg, BATCH, 16, enc_len=12)
        cache = E.encdec_prefill_cross(params, cache, enc_out, cfg)
        tok = jnp.zeros((BATCH,), jnp.int32)
        for _ in range(3):
            logits, cache = E.encdec_decode_step(params, cache, tok, cfg)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert logits.shape == (BATCH, cfg.vocab_size)
        assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
        return
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    cache = T.lm_init_cache(cfg, BATCH, 16)
    tok = jnp.zeros((BATCH,), jnp.int32)
    # repro-lint: disable=R003 reason=one-shot test body wrapper
    step = jax.jit(lambda p, c, t: T.lm_decode_step(p, c, t, cfg))
    for _ in range(3):
        logits, cache = step(params, cache, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert int(cache["pos"][0]) == 3


def test_decode_matches_forward_qwen3():
    """Teacher-forced forward and step-by-step decode agree."""
    cfg = dataclasses.replace(get_config("qwen3-0.6b", smoke=True),
                              dtype=jnp.float32,
                              mf=MFTechniqueConfig(enabled=False))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    logits_full, _ = T.lm_forward(params, {"tokens": tokens}, cfg)
    cache = T.lm_init_cache(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = T.lm_decode_step(params, cache, tokens[:, t], cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_decode_matches_forward_hybrid():
    """Same agreement for the recurrent/local-attention hybrid."""
    cfg = dataclasses.replace(get_config("recurrentgemma-2b", smoke=True),
                              dtype=jnp.float32,
                              mf=MFTechniqueConfig(enabled=False))
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0,
                                cfg.vocab_size)
    logits_full, _ = T.lm_forward(params, {"tokens": tokens}, cfg)
    cache = T.lm_init_cache(cfg, 1, 16)
    outs = []
    for t in range(8):
        lg, cache = T.lm_decode_step(params, cache, tokens[:, t], cfg)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(logits_full),
                               rtol=2e-3, atol=2e-3)


def test_loss_decreases_with_mf():
    cfg = get_config("qwen3-0.6b", smoke=True)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)
    tcfg = TrainConfig(lr=3e-3, warmup_steps=3, total_steps=40)
    state = TL.init_state(jax.random.PRNGKey(0), cfg, tcfg)
    # repro-lint: disable=R003 reason=one-shot test body wrapper
    step = jax.jit(TL.make_train_step(cfg, ParallelConfig(remat="none"),
                                      tcfg))
    dcfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=8,
                      task="copy")
    losses = []
    for i in range(40):
        state, m = step(state, jax.tree.map(jnp.asarray, lm_batch(dcfg, i)))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]


def test_long_context_ring_cache_is_bounded():
    """local_attn decode keeps O(window) memory: cache smaller than T."""
    cfg = get_config("recurrentgemma-2b", smoke=True)  # window 16
    cache = T.lm_init_cache(cfg, 2, max_len=4096)
    k = cache["layers"][2]["attn"]["k"]  # local_attn position in pattern
    assert k.shape[2] == cfg.window  # ring buffer, not 4096
