"""MoE unit tests: router, dense path vs manual reference, aux loss,
capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod


def _params(key, d=16, dff=8, e=4, shared=0):
    return moe_mod.moe_init(key, d, dff, e, shared, top_k=2, mf=False,
                            dtype=jnp.float32)


class TestRouter:
    def test_topk_weights_normalised(self):
        p = _params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 16))
        w, ids, aux = moe_mod._router(p, x, 2)
        np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0,
                                   rtol=1e-5)
        assert int(jnp.max(ids)) < 4 and int(jnp.min(ids)) >= 0
        assert float(aux) >= 1.0 - 1e-5      # E * sum f*P >= 1 at optimum

    def test_aux_loss_penalises_collapse(self):
        # all tokens to one expert -> aux ~ E; uniform -> aux ~ 1
        p = _params(jax.random.PRNGKey(0))
        e = 4
        probs_collapsed = jnp.zeros((8, e)).at[:, 0].set(1.0)
        me = jnp.mean(probs_collapsed, axis=0)
        ce = jnp.mean(jax.nn.one_hot(jnp.zeros(8, jnp.int32), e), axis=0)
        aux_collapsed = e * jnp.sum(me * ce)
        assert float(aux_collapsed) == e


class TestDensePath:
    def test_matches_manual_reference(self):
        key = jax.random.PRNGKey(0)
        p = _params(key)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
        y, aux = moe_mod.moe_apply_dense(p, x, top_k=2)

        # manual: per token, weighted sum of its top-2 experts' FFNs
        w, ids, _ = moe_mod._router(p, x, 2)
        ref = jnp.zeros_like(x)
        for t in range(5):
            acc = jnp.zeros((16,))
            for k in range(2):
                e = int(ids[t, k])
                h = x[t]
                z = (jax.nn.silu(h @ p["experts"]["gate"][e])
                     * (h @ p["experts"]["up"][e]))
                acc = acc + w[t, k] * (z @ p["experts"]["down"][e])
            ref = ref.at[t].set(acc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_shared_expert_added(self):
        key = jax.random.PRNGKey(0)
        p0 = _params(key, shared=0)
        p1 = _params(key, shared=1)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
        y0, _ = moe_mod.moe_apply_dense(p0, x, top_k=2)
        y1, _ = moe_mod.moe_apply_dense(p1, x, top_k=2)
        assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-4

    def test_gradients_flow_to_router_and_experts(self):
        p = _params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))

        def loss(pp):
            y, aux = moe_mod.moe_apply_dense(pp, x, top_k=2)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        assert float(jnp.max(jnp.abs(g["router"]["w"]))) > 0
        assert float(jnp.max(jnp.abs(g["experts"]["up"]))) > 0


class TestSegmentPositions:
    def test_positions_within_sorted_segments(self):
        ids = jnp.asarray([0, 0, 1, 1, 1, 3])
        pos = moe_mod._segment_positions(ids, 4)
        np.testing.assert_array_equal(np.asarray(pos), [0, 1, 0, 1, 2, 0])
