"""MoE unit tests: router, dense path vs manual reference, aux loss,
capacity semantics."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import moe as moe_mod


def _params(key, d=16, dff=8, e=4, shared=0):
    return moe_mod.moe_init(key, d, dff, e, shared, top_k=2, mf=False,
                            dtype=jnp.float32)


class TestRouter:
    def test_topk_weights_normalised(self):
        p = _params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (6, 16))
        w, ids, aux = moe_mod._router(p, x, 2)
        np.testing.assert_allclose(np.asarray(jnp.sum(w, -1)), 1.0,
                                   rtol=1e-5)
        assert int(jnp.max(ids)) < 4 and int(jnp.min(ids)) >= 0
        assert float(aux) >= 1.0 - 1e-5      # E * sum f*P >= 1 at optimum

    def test_aux_loss_penalises_collapse(self):
        # all tokens to one expert -> aux ~ E; uniform -> aux ~ 1
        e = 4
        probs_collapsed = jnp.zeros((8, e)).at[:, 0].set(1.0)
        me = jnp.mean(probs_collapsed, axis=0)
        ce = jnp.mean(jax.nn.one_hot(jnp.zeros(8, jnp.int32), e), axis=0)
        aux_collapsed = e * jnp.sum(me * ce)
        assert float(aux_collapsed) == e


class TestDensePath:
    def test_matches_manual_reference(self):
        key = jax.random.PRNGKey(0)
        p = _params(key)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
        y, aux = moe_mod.moe_apply_dense(p, x, top_k=2)

        # manual: per token, weighted sum of its top-2 experts' FFNs
        w, ids, _ = moe_mod._router(p, x, 2)
        ref = jnp.zeros_like(x)
        for t in range(5):
            acc = jnp.zeros((16,))
            for k in range(2):
                e = int(ids[t, k])
                h = x[t]
                z = (jax.nn.silu(h @ p["experts"]["gate"][e])
                     * (h @ p["experts"]["up"][e]))
                acc = acc + w[t, k] * (z @ p["experts"]["down"][e])
            ref = ref.at[t].set(acc)
        np.testing.assert_allclose(np.asarray(y), np.asarray(ref),
                                   rtol=1e-4, atol=1e-5)

    def test_shared_expert_added(self):
        key = jax.random.PRNGKey(0)
        p0 = _params(key, shared=0)
        p1 = _params(key, shared=1)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))
        y0, _ = moe_mod.moe_apply_dense(p0, x, top_k=2)
        y1, _ = moe_mod.moe_apply_dense(p1, x, top_k=2)
        assert float(jnp.max(jnp.abs(y0 - y1))) > 1e-4

    def test_gradients_flow_to_router_and_experts(self):
        p = _params(jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16))

        def loss(pp):
            y, aux = moe_mod.moe_apply_dense(pp, x, top_k=2)
            return jnp.sum(y ** 2) + 0.01 * aux

        g = jax.grad(loss)(p)
        assert float(jnp.max(jnp.abs(g["router"]["w"]))) > 0
        assert float(jnp.max(jnp.abs(g["experts"]["up"]))) > 0


class TestSegmentPositions:
    def test_positions_within_sorted_segments(self):
        ids = jnp.asarray([0, 0, 1, 1, 1, 3])
        pos = moe_mod._segment_positions(ids, 4)
        np.testing.assert_array_equal(np.asarray(pos), [0, 1, 0, 1, 2, 0])


class TestProgrammedExperts:
    """Weight-stationary MoE: program_weights threads ProgrammedMacro
    state through the experts[up/gate/down] layout (ISSUE 3 satellite)."""

    def _setup(self):
        from repro.core import quant
        from repro.core.cim import CimConfig, cim_mf_matmul
        cim = CimConfig(8, 8, 5, 31)
        p = moe_mod.moe_init(jax.random.PRNGKey(0), 16, 8, 4, 0, top_k=2,
                             mf=True, dtype=jnp.float32)
        x = jax.random.normal(jax.random.PRNGKey(1), (5, 16))
        # Scales matching what the on-the-fly path calibrates dynamically:
        # up/gate see x; each expert's down sees its own z.
        sx_x = float(quant.calibrate_scale(x, cim.x_bits))
        z_scales = []
        for e in range(4):
            zu = (cim_mf_matmul(x, p["experts"]["up"][e], cim)
                  * p["experts"]["alpha_up"][e])
            zg = (cim_mf_matmul(x, p["experts"]["gate"][e], cim)
                  * p["experts"]["alpha_up"][e])
            z = jax.nn.silu(zg) * zu
            z_scales.append(float(quant.calibrate_scale(z, cim.x_bits)))
        scales = {"experts.up": np.full((4,), sx_x, np.float32),
                  "experts.gate": np.full((4,), sx_x, np.float32),
                  "experts.down": np.asarray(z_scales, np.float32)}
        from repro.core.programmed import program_weights
        pp = program_weights(p, cim, scales=scales)
        return cim, p, pp, x

    def test_program_weights_attaches_expert_state(self):
        cim, p, pp, x = self._setup()
        assert {"prog_up", "prog_gate", "prog_down"} <= set(pp["experts"])
        # stacked leading E on every programmed leaf (scan/vmap sliceable)
        for leaf in jax.tree.leaves(pp["experts"]["prog_up"]):
            assert leaf.shape[0] == 4
        from repro.core.programmed import strip_programmed
        assert jax.tree.structure(strip_programmed(pp)) == \
            jax.tree.structure(p)

    def test_expert_ffn_bit_exact_per_expert(self):
        cim, p, pp, x = self._setup()
        for e in range(4):
            ep_ref = jax.tree.map(lambda v: v[e], p["experts"])
            ep_prog = jax.tree.map(lambda v: v[e], pp["experts"])
            y_ref = moe_mod._expert_ffn(ep_ref, slice(None), x, "cim_sim",
                                        cim_cfg=cim)
            y_prog = moe_mod._expert_ffn(ep_prog, slice(None), x, "cim_sim",
                                         cim_cfg=cim)
            np.testing.assert_array_equal(np.asarray(y_ref),
                                          np.asarray(y_prog))

    def test_dense_path_runs_programmed_and_matches(self):
        # The scan-compiled programmed and on-the-fly programs are
        # different XLA programs, so cross-program FMA fusion may differ
        # in the last ulp — the macro arithmetic itself is bit-exact
        # (asserted per-expert above).
        cim, p, pp, x = self._setup()
        y_ref, aux_ref = moe_mod.moe_apply_dense(p, x, top_k=2,
                                                 mode="cim_sim", cim_cfg=cim)
        y_prog, aux_prog = moe_mod.moe_apply_dense(pp, x, top_k=2,
                                                   mode="cim_sim",
                                                   cim_cfg=cim)
        np.testing.assert_array_equal(np.asarray(aux_ref),
                                      np.asarray(aux_prog))
        np.testing.assert_allclose(np.asarray(y_ref), np.asarray(y_prog),
                                   rtol=0, atol=1e-6)

    def test_default_programming_covers_experts_in_model(self):
        # End to end: a MoE ModelConfig programs at engine construction
        # and decodes from expert macro state.
        from repro.configs.base import (MFTechniqueConfig, ModelConfig,
                                        MoEConfig)
        from repro.core.cim import CimConfig
        from repro.core.programmed import program_weights
        from repro.models import transformer as T
        cfg = ModelConfig(
            name="moe-prog-tiny", family="moe", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
            dtype=jnp.float32,
            moe=MoEConfig(n_experts=4, top_k=2, n_shared=1, d_ff_expert=32),
            mf=MFTechniqueConfig(mode="cim_sim",
                                 cim=CimConfig(4, 4, 5, 31)))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        pp = program_weights(params, cfg.mf.cim)
        layer_moe = pp["layers"][0]["moe"]["experts"]
        assert {"prog_up", "prog_gate", "prog_down"} <= set(layer_moe)
        cache = T.lm_init_cache(cfg, 2, 8)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        step = jax.jit(lambda p_, c, t: T.lm_decode_step(p_, c, t, cfg))
        logits, _ = step(pp, cache, jnp.array([1, 2]))
        assert logits.shape == (2, 64)
        assert bool(jnp.all(jnp.isfinite(logits)))
