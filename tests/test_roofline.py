"""Roofline analyzer tests: HLO collective parser, terms, param counting."""

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:  # deterministic fallback, see tests/_hypothesis_stub.py
    from _hypothesis_stub import hypothesis, st
import jax
import jax.numpy as jnp

from repro.roofline import analysis as R

HLO_SAMPLE = """
HloModule test
ENTRY main {
  %p0 = f32[8,128]{1,0} parameter(0)
  %ag = f32[8,2048]{1,0} all-gather(%p0), dimensions={1}
  %ar = bf16[1024]{0} all-reduce(%x), to_apply=%add
  %rs = f32[4,64]{1,0} reduce-scatter(%y), dimensions={0}
  %a2a = s32[16,32]{1,0} all-to-all(%z)
  %cp = bf16[2,2]{1,0} collective-permute(%w)
  %ars = f32[512]{0} all-reduce-start(%v)
  %dot = f32[8,8]{1,0} dot(%p0, %p0t)
}
"""


class TestCollectiveParser:
    def test_counts_each_kind(self):
        out = R.collective_bytes(HLO_SAMPLE)
        assert out["all-gather"] == 8 * 2048 * 4
        # all-reduce + all-reduce-start both count
        assert out["all-reduce"] == 1024 * 2 + 512 * 4
        assert out["reduce-scatter"] == 4 * 64 * 4
        assert out["all-to-all"] == 16 * 32 * 4
        assert out["collective-permute"] == 2 * 2 * 2

    def test_ignores_non_collectives(self):
        out = R.collective_bytes("%d = f32[64,64]{1,0} dot(%a, %b)")
        assert sum(out.values()) == 0

    @hypothesis.given(st.integers(1, 64), st.integers(1, 64))
    @hypothesis.settings(max_examples=20, deadline=None)
    def test_shape_bytes(self, a, b):
        assert R._shape_bytes(f"f32[{a},{b}]") == a * b * 4
        assert R._shape_bytes(f"bf16[{a}]") == a * 2

    def test_tuple_result(self):
        s = "%t = (f32[8]{0}, bf16[4]{0}) all-reduce(%a, %b)"
        out = R.collective_bytes(s)
        assert out["all-reduce"] == 8 * 4 + 4 * 2


class TestTerms:
    def test_dominant_and_seconds(self):
        t = R.RooflineTerms(flops=R.PEAK_FLOPS, hbm_bytes=R.HBM_BW * 2,
                            coll_bytes=R.LINK_BW * 0.5, chips=1)
        assert t.compute_s == 1.0
        assert t.memory_s == 2.0
        assert t.collective_s == 0.5
        assert t.dominant == "memory"
        assert t.bound_s == 2.0

    def test_real_compiled_cost(self):
        f = jax.jit(lambda a, b: a @ b)
        x = jnp.ones((128, 128))
        c = f.lower(x, x).compile()
        t = R.terms_from_compiled(c, chips=1)
        # 2*M*N*K flops for a 128^3 matmul
        assert t.flops >= 2 * 128 ** 3 * 0.9
        assert t.coll_bytes == 0


class TestModelFlops:
    def test_count_params_moe_active_fraction(self):
        tree = {"layers": {"moe": {"experts": {
            "up": jax.ShapeDtypeStruct((8, 4, 16), jnp.float32)},
            "router": {"w": jax.ShapeDtypeStruct((4, 8), jnp.float32)}}},
            "embed": {"table": jax.ShapeDtypeStruct((10, 4), jnp.float32)}}
        counts = R.count_params(tree, active_expert_fraction=0.25)
        total_experts = 8 * 4 * 16
        assert counts["total"] == total_experts + 32 + 40
        assert counts["active"] == int(total_experts * 0.25) + 32 + 40

    def test_model_flops_conventions(self):
        assert R.model_flops(100, 10, "train") == 6 * 100 * 10
        assert R.model_flops(100, 10, "decode") == 2 * 100 * 10
