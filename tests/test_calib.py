"""Calibration lab: observer semantics, corpus coverage, scale
programming, artifact round-trip, and the engine's calibration hook.

Contracts under test (ISSUE 3):
  * ObserverState is an exact commutative monoid — merging is
    order-invariant bit for bit, the empty state is an identity, updates
    are jit/vmap-safe and empty batches are no-ops.
  * One observe pass over any registered architecture records statistics
    for EVERY MF projection instance — scan-stacked layers, MLA, MoE
    experts, rgLRU, xLSTM, and convs included.
  * ``program_weights(scales=...)`` programs per-instance scales under
    the names the observer registry emits, and programming the static
    default THROUGH the scales hook is bit-identical to the default path.
  * CalibrationArtifact save/load round-trips scales bit-exactly.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.calib import tap
from repro.calib.artifact import CalibrationArtifact
from repro.calib.corpus import (ErrorCollector, StatsCollector,
                                attach_observer_ids, collect_stats,
                                scales_from_stats, strip_observer_ids)
from repro.calib.observers import (ObserverConfig, channel_amax,
                                   observer_init, observer_merge,
                                   observer_update, scale_amax, scale_mse,
                                   scale_percentile, select_scale,
                                   shape_scale_channels, summarize)
from repro.core import quant
from repro.core.cim import CimConfig
from repro.core.programmed import (default_static_sx, iter_projections,
                                   program_weights)

OBS = ObserverConfig(n_bins=64, range_max=8.0)


def _states(n, key=0):
    xs = [jax.random.normal(jax.random.PRNGKey(key + i), (13, 7)) * (i + 1)
          for i in range(n)]
    return xs, [summarize(x, OBS) for x in xs]


class TestObserverSemantics:
    def test_merge_order_invariant(self):
        xs, sts = _states(5)
        fwd = functools.reduce(observer_merge, sts)
        rev = functools.reduce(observer_merge, sts[::-1])
        tree = observer_merge(observer_merge(sts[3], sts[1]),
                              observer_merge(observer_merge(sts[0], sts[4]),
                                             sts[2]))
        for other in (rev, tree):
            np.testing.assert_array_equal(np.asarray(fwd.count),
                                          np.asarray(other.count))
            np.testing.assert_array_equal(np.asarray(fwd.amax),
                                          np.asarray(other.amax))
            np.testing.assert_array_equal(np.asarray(fwd.hist),
                                          np.asarray(other.hist))

    def test_merge_matches_sequential_update(self):
        xs, sts = _states(3)
        seq = observer_init(OBS)
        for x in xs:
            seq = observer_update(seq, x, OBS)
        merged = functools.reduce(observer_merge, sts)
        np.testing.assert_array_equal(np.asarray(seq.hist),
                                      np.asarray(merged.hist))
        np.testing.assert_array_equal(np.asarray(seq.amax),
                                      np.asarray(merged.amax))

    def test_empty_state_is_identity(self):
        _, (st,) = _states(1)
        out = observer_merge(st, observer_init(OBS))
        for a, b in zip(st, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_empty_batch_is_noop(self):
        _, (st,) = _states(1)
        out = observer_update(st, jnp.zeros((0, 5)), OBS)
        for a, b in zip(st, out):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_update_under_jit(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (6, 4))
        eager = observer_update(observer_init(OBS), x, OBS)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        jitted = jax.jit(lambda s, v: observer_update(s, v, OBS))(
            observer_init(OBS), x)
        np.testing.assert_array_equal(np.asarray(eager.hist),
                                      np.asarray(jitted.hist))

    def test_update_under_vmap(self):
        xs = jax.random.normal(jax.random.PRNGKey(0), (3, 6, 4))
        init = jax.tree.map(lambda v: jnp.broadcast_to(v, (3,) + v.shape),
                            observer_init(OBS))
        batched = jax.vmap(lambda s, v: observer_update(s, v, OBS))(init, xs)
        for i in range(3):
            one = observer_update(observer_init(OBS), xs[i], OBS)
            np.testing.assert_array_equal(np.asarray(batched.hist[i]),
                                          np.asarray(one.hist))
            np.testing.assert_array_equal(np.asarray(batched.amax[i]),
                                          np.asarray(one.amax))

    def test_count_tracks_elements(self):
        xs, sts = _states(4)
        merged = functools.reduce(observer_merge, sts)
        assert float(merged.count) == sum(x.size for x in xs)
        assert float(jnp.sum(merged.hist)) == float(merged.count)


class TestScaleSelection:
    def test_amax_scale(self):
        st = summarize(jnp.asarray([0.5, -2.0, 1.0]), OBS)
        assert scale_amax(st, 8) == pytest.approx(2.0 / 127.0)

    def test_fallback_on_empty(self):
        st = observer_init(OBS)
        for method in ("amax", "percentile", "mse"):
            assert select_scale(st, 8, method, cfg=OBS,
                                fallback_amax=4.0) == pytest.approx(4.0 / 127)

    def test_percentile_and_mse_clip_outliers(self):
        # 10k unit-scale values + one 6-sigma spike: amax covers the
        # spike; percentile/MSE clip it and win resolution.
        v = jax.random.normal(jax.random.PRNGKey(0), (10_000,))
        v = jnp.concatenate([v, jnp.asarray([6.5])])
        st = summarize(v, OBS)
        s_amax = scale_amax(st, 8)
        s_pct = scale_percentile(st, 8, pct=99.9, cfg=OBS)
        s_mse = scale_mse(st, 8, cfg=OBS)
        assert s_pct < s_amax
        assert s_mse < s_amax
        assert s_pct > 0 and s_mse > 0


class TestArtifactRoundTrip:
    def test_save_load_bit_exact(self, tmp_path):
        rng = np.random.default_rng(0)
        art = CalibrationArtifact(
            method="mse", x_bits=8,
            scales={
                "layers.0.attn.q": rng.random((3,), np.float32) * 0.03,
                "layers.0.moe.experts.up": rng.random((3, 4),
                                                      np.float32) * 0.02,
                "tail.0.mlp.up": np.float32(0.0123) * np.ones((),
                                                              np.float32),
            },
            meta={"model": "test", "n_batches": 4})
        path = str(tmp_path / "calib.json")
        art.save(path)
        back = CalibrationArtifact.load(path)
        assert back.method == art.method and back.x_bits == art.x_bits
        assert back.meta == art.meta
        assert set(back.scales) == set(art.scales)
        for name in art.scales:
            assert back.scales[name].shape == np.shape(art.scales[name])
            np.testing.assert_array_equal(back.scales[name],
                                          art.scales[name])

    def test_load_rejects_foreign_json(self, tmp_path):
        path = str(tmp_path / "other.json")
        with open(path, "w") as f:
            f.write('{"bench": "serve_decode"}\n')
        with pytest.raises(ValueError, match="not a calibration artifact"):
            CalibrationArtifact.load(path)


def _mk_cfg(**kw):
    from repro.configs.base import MFTechniqueConfig, ModelConfig
    base = dict(name="calib-tiny", family="lm", n_layers=2, d_model=32,
                n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
                dtype=jnp.float32,
                mf=MFTechniqueConfig(mode="mf"))
    base.update(kw)
    return ModelConfig(**base)


def _observe_lm(cfg, batch_tokens):
    from repro.models import transformer as T
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    tagged, registry = attach_observer_ids(params)
    collector = collect_stats(
        lambda p, b: T.lm_forward(p, b, cfg)[0], tagged,
        [{"tokens": batch_tokens}], registry, OBS)
    return params, registry, collector


class TestCorpusCoverage:
    """One observe pass records stats for EVERY projection instance."""

    def _assert_full_coverage(self, registry, collector):
        assert registry.n_ids > 0
        for name, (off, shape) in registry.entries.items():
            n = int(np.prod(shape, dtype=np.int64)) if shape else 1
            for j in range(n):
                assert collector.count[off + j] > 0, (name, j)

    def test_scan_stacked_attention_lm(self):
        cfg = _mk_cfg()
        tokens = jnp.ones((2, 8), jnp.int32)
        params, registry, collector = _observe_lm(cfg, tokens)
        # 2 stacked layers: q/k/v/o + mlp up/gate/down, one id per period
        assert any(shape == (2,) for _, shape in registry.entries.values())
        self._assert_full_coverage(registry, collector)

    def test_mla_moe_experts(self):
        from repro.configs.deepseek_v3_671b import SMOKE as DS
        cfg = dataclasses.replace(
            DS, mf=dataclasses.replace(DS.mf, mode="mf"))
        tokens = jnp.ones((2, 8), jnp.int32)
        params, registry, collector = _observe_lm(cfg, tokens)
        expert_names = [n for n in registry.entries
                        if ".experts." in n
                        and n.endswith((".up", ".gate", ".down"))]
        assert expert_names, "no expert banks registered"
        # per-expert instances: leading shape ends with n_experts
        assert all(registry.entries[n][1][-1] == DS.moe.n_experts
                   for n in expert_names)
        self._assert_full_coverage(registry, collector)

    def test_rglru_hybrid(self):
        from repro.configs.recurrentgemma_2b import SMOKE as RG
        cfg = dataclasses.replace(
            RG, mf=dataclasses.replace(RG.mf, mode="mf"))
        tokens = jnp.ones((2, 8), jnp.int32)
        _, registry, collector = _observe_lm(cfg, tokens)
        self._assert_full_coverage(registry, collector)

    def test_xlstm(self):
        from repro.configs.xlstm_350m import SMOKE as XL
        cfg = dataclasses.replace(
            XL, mf=dataclasses.replace(XL.mf, mode="mf"))
        tokens = jnp.ones((2, 8), jnp.int32)
        _, registry, collector = _observe_lm(cfg, tokens)
        self._assert_full_coverage(registry, collector)

    def test_conv_lenet(self):
        from repro.models import convnets as C
        params = C.lenet_init(jax.random.PRNGKey(0))
        tagged, registry = attach_observer_ids(params)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 28, 28, 1))
        modes = {"conv1": "mf", "conv2": "mf", "fc1": "mf",
                 "fc2": "regular"}
        collector = collect_stats(
            lambda p, b: C.lenet_apply(p, b, modes), tagged, [x],
            registry, OBS)
        assert set(registry.entries) == {"conv1", "conv2", "fc1"}
        self._assert_full_coverage(registry, collector)

    def test_strip_observer_ids_round_trip(self):
        cfg = _mk_cfg()
        from repro.models import transformer as T
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, _ = attach_observer_ids(params)
        assert jax.tree.structure(strip_observer_ids(tagged)) == \
            jax.tree.structure(params)


class TestScaleProgramming:
    def _cim_cfg(self):
        from repro.configs.base import MFTechniqueConfig
        return dataclasses.replace(
            _mk_cfg(), mf=MFTechniqueConfig(mode="cim_sim",
                                            cim=CimConfig(8, 8, 5, 31)))

    def test_per_instance_scales_land_in_prog(self):
        from repro.models import transformer as T
        cfg = self._cim_cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        names = [n for n, _, k in iter_projections(params) if k == "linear"]
        stacked = [n for n in names if n.startswith("layers.")]
        assert stacked
        target = stacked[0]
        scales = {target: np.asarray([0.011, 0.022], np.float32)}
        pp = program_weights(params, cfg.mf.cim, scales=scales)
        node = pp
        for seg in target.split("."):
            node = node[int(seg)] if seg.isdigit() else node[seg]
        np.testing.assert_array_equal(np.asarray(node["prog"].sx),
                                      scales[target])
        # unnamed projections fall back to the static default
        other = [n for n in stacked if n != target][0]
        node = pp
        for seg in other.split("."):
            node = node[int(seg)] if seg.isdigit() else node[seg]
        np.testing.assert_allclose(
            np.asarray(node["prog"].sx),
            np.full((2,), default_static_sx(cfg.mf.cim), np.float32),
            rtol=0)

    def test_static_scales_through_hook_bit_exact(self):
        from repro.models import transformer as T
        cfg = self._cim_cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        _, registry = attach_observer_ids(params)
        sx = np.float32(default_static_sx(cfg.mf.cim))
        scales = {name: np.full(shape or (), sx, np.float32)
                  for name, (_, shape) in registry.entries.items()}
        pa = program_weights(params, cfg.mf.cim)
        pb = program_weights(params, cfg.mf.cim, scales=scales)
        cache = T.lm_init_cache(cfg, 2, 8)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        step = jax.jit(lambda p, c, t: T.lm_decode_step(p, c, t, cfg))
        la, _ = step(pa, cache, jnp.array([1, 2]))
        cache = T.lm_init_cache(cfg, 2, 8)
        lb, _ = step(pb, cache, jnp.array([1, 2]))
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))

    def test_calibrated_scale_beats_static_on_quiet_signal(self):
        # A projection whose inputs live at |x| <= 0.5: the full-scale
        # static grid (amax 4.0) wastes 3 bits; the measured amax scale
        # recovers them — strictly higher SQNR vs the float MF reference.
        from repro.core.mf import mf_correlate_ref
        from repro.core.programmed import (cim_mf_matmul_programmed,
                                           program_macro)
        cim = CimConfig(8, 8, 5, 31)
        x = 0.5 * jax.random.normal(jax.random.PRNGKey(0), (64, 70))
        x = jnp.clip(x, -0.5, 0.5)
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        ref = np.asarray(mf_correlate_ref(x, w, hw=True))
        st = summarize(x, OBS)

        def sqnr(sx):
            y = np.asarray(cim_mf_matmul_programmed(
                x, program_macro(w, cim, sx=sx), cim))
            return 10 * np.log10((ref ** 2).sum() / ((y - ref) ** 2).sum())

        s_static = sqnr(default_static_sx(cim))
        s_calib = sqnr(scale_amax(st, cim.x_bits))
        # the weight-side quantisation error is unchanged, so the gain
        # saturates below the 3 recovered input bits — but it must be
        # decisively positive.
        assert s_calib > s_static + 2.0

    def test_conv_programmed_parity(self):
        # conv_apply consumes the programmed im2col macro bit-exactly
        # against the on-the-fly CIM path when programmed with the
        # dynamic patch scale.
        from repro.models import convnets as C
        cim = CimConfig(8, 8, 5, 31)
        p = C.conv_init(jax.random.PRNGKey(0), 3, 3, 2, 5, mf=True)
        x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, 2))
        y_ref = np.asarray(C.conv_apply(p, x, "cim_sim", cim_cfg=cim))
        patches = jax.lax.conv_general_dilated_patches(
            x, (3, 3), (1, 1), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"))
        sx = quant.calibrate_scale(patches.reshape(-1, 18), cim.x_bits)
        pp = program_weights({"c": p}, cim,
                             scales={"c": np.float32(sx)})["c"]
        assert "prog" in pp
        y_prog = np.asarray(C.conv_apply(pp, x, "cim_sim", cim_cfg=cim))
        np.testing.assert_array_equal(y_ref, y_prog)


class TestPerChannelCalibration:
    """Per-feature amax profiles -> (lead..., K) scale vectors -> DAC
    gain trims (the per-channel `sx` satellite of ISSUE 7)."""

    def test_channel_amax_matches_numpy(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (4, 6, 10))
        got = np.asarray(channel_amax(x))
        want = np.abs(np.asarray(x)).reshape(-1, 10).max(axis=0)
        np.testing.assert_array_equal(got, want.astype(np.float32))
        assert np.asarray(channel_amax(jnp.zeros((0, 5)))).shape == (5,)

    def test_collector_merges_channel_profiles(self):
        col = StatsCollector(1, OBS)
        xs = [jax.random.normal(jax.random.PRNGKey(i), (8, 6)) * (i + 1)
              for i in range(3)]
        for x in xs:
            col.emit_activation(jnp.int32(0), x)
        jax.effects_barrier()
        want = np.max([np.abs(np.asarray(x)).max(axis=0) for x in xs],
                      axis=0)
        np.testing.assert_allclose(col.channel_state(0), want, rtol=1e-6)
        assert col.channel_state(0).max() == pytest.approx(col.amax[0])

    def test_shape_scale_channels(self):
        camax = np.asarray([4.0, 2.0, 0.001, 0.0], np.float64)
        v = shape_scale_channels(0.05, camax, floor=2.0 ** -8)
        assert v.dtype == np.float32
        assert v[0] == pytest.approx(0.05)           # loudest keeps scale
        assert v[1] == pytest.approx(0.025)          # proportional trim
        floor = np.float32(0.05 * 2.0 ** -8)
        assert v[2] == floor and v[3] == floor       # floored, not zeroed
        # silence degenerates to the uniform scalar scale
        np.testing.assert_array_equal(
            shape_scale_channels(0.05, np.zeros((3,))),
            np.full((3,), 0.05, np.float32))

    def test_scales_from_stats_per_channel_shapes(self):
        cfg = _mk_cfg()
        tokens = jnp.ones((2, 8), jnp.int32)
        params, registry, collector = _observe_lm(cfg, tokens)
        scalar = scales_from_stats(collector, registry, 8, "amax")
        pc = scales_from_stats(collector, registry, 8, "amax",
                               per_channel=True)
        ks = {name: node["w"].shape[-2]
              for name, node, kind in iter_projections(params)
              if kind == "linear"}
        for name, (_, shape) in registry.entries.items():
            assert pc[name].shape == shape + (ks[name],), name
            # max gain is exactly 1 -> the loudest channel keeps the
            # scalar policy scale per instance
            np.testing.assert_allclose(pc[name].max(axis=-1),
                                       scalar[name], rtol=1e-6)

    def test_unfired_projection_stays_scalar(self):
        from repro.calib.corpus import ObserverRegistry
        registry = ObserverRegistry({"p": (0, ())}, 1)
        empty = StatsCollector(1, OBS)
        pc = scales_from_stats(empty, registry, 8, "amax",
                               per_channel=True)
        assert pc["p"].shape == ()      # nothing to profile -> per-tensor

    def test_per_channel_programs_and_serves(self):
        from repro.models import transformer as T
        from repro.configs.base import MFTechniqueConfig
        cfg = dataclasses.replace(
            _mk_cfg(), mf=MFTechniqueConfig(mode="cim_sim",
                                            cim=CimConfig(8, 8, 5, 31)))
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        tagged, registry = attach_observer_ids(params)
        collector = collect_stats(
            lambda p, b: T.lm_forward(
                p, b, dataclasses.replace(
                    cfg, mf=dataclasses.replace(cfg.mf, mode="mf")))[0],
            tagged, [{"tokens": jnp.ones((2, 8), jnp.int32)}], registry,
            OBS)
        pc = scales_from_stats(collector, registry, 8, "mse",
                               per_channel=True)
        progd = program_weights(tagged, cfg.mf.cim, scales=pc)
        node = progd
        first = sorted(registry.entries)[0]
        for seg in first.split("."):
            node = node[int(seg)] if seg.isdigit() else node[seg]
        assert node["prog"].dac_gains is not None
        assert node["prog"].sx.shape == registry.entries[first][1]
        cache = T.lm_init_cache(cfg, 2, 8)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        logits, _ = jax.jit(
            lambda p, c, t: T.lm_decode_step(p, c, t, cfg))(
                progd, cache, jnp.array([1, 2]))
        assert np.isfinite(np.asarray(logits)).all()

    def test_per_channel_sqnr_sign_flips_with_adc_provisioning(self):
        # The documented finding (BENCH_calib per_channel_sqnr_delta_db):
        # DAC gain trims refine the input grid but attenuate each
        # channel's charge contribution, so they HURT at an exactly
        # lossless pairing (gain-weighted averages break the code==count
        # identity — every S2/R_x conversion picks up real ADC rounding)
        # and HELP when the ADC is the bottleneck anyway (31x4). Assert
        # both directions on a half-quiet projection.
        from repro.core.mf import mf_correlate_ref
        from repro.core.programmed import (cim_mf_matmul_programmed,
                                           program_macro)
        x = jax.random.normal(jax.random.PRNGKey(0), (64, 70))
        x = x * jnp.where(jnp.arange(70) < 35, 1.0, 0.01)[None, :]
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        ref = np.asarray(mf_correlate_ref(x, w, hw=True))
        st = summarize(x, OBS)
        camax = np.asarray(channel_amax(x))

        def sqnr(cim, sx):
            y = np.asarray(cim_mf_matmul_programmed(
                x, program_macro(w, cim, sx=jnp.asarray(sx)), cim))
            return 10 * np.log10((ref ** 2).sum() / ((y - ref) ** 2).sum())

        def delta(cim):
            s = scale_amax(st, cim.x_bits)
            return (sqnr(cim, shape_scale_channels(s, camax))
                    - sqnr(cim, np.float32(s)))

        assert delta(CimConfig(8, 8, 5, 31)) < -10.0   # lossless: hurts
        assert delta(CimConfig(8, 8, 4, 31)) > 0.5     # starved ADC: helps

    def test_swap_rejects_per_channel(self):
        from repro.core.programmed import swap_macro
        cim = CimConfig(8, 8, 5, 31)
        w = jax.random.normal(jax.random.PRNGKey(0), (62, 4))
        with pytest.raises(NotImplementedError, match="swap-scheduled"):
            swap_macro(w, cim, tile_slots=3,
                       sx=jnp.full((62,), 0.03, jnp.float32))

    def test_injection_rejects_dac_gains(self):
        from repro.core.programmed import (cim_mf_matmul_programmed,
                                           program_macro)
        from repro.silicon import (SiliconConfig, projection_silicon,
                                   sample_fleet)
        cim = CimConfig(8, 8, 5, 31)
        x = jax.random.normal(jax.random.PRNGKey(0), (3, 70))
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        prog = program_macro(w, cim, sx=jnp.full((70,), 0.03, jnp.float32))
        assert prog.dac_gains is not None
        scfg = SiliconConfig(cap_sigma=0.08, comparator_sigma_v=0.01)
        fleet = sample_fleet(jax.random.PRNGKey(2), 24, 31, scfg)
        sil = projection_silicon(fleet, scfg, 70, 9)
        with pytest.raises(ValueError, match="per-channel"):
            cim_mf_matmul_programmed(x, prog, cim, silicon=sil)


class TestErrorCollector:
    def test_sqnr_accumulates_and_caps(self):
        col = ErrorCollector(2)
        y = jnp.asarray([3.0, 4.0])
        col.emit_error(jnp.int32(0), y, y + 0.1)
        col.emit_error(jnp.int32(1), y, y)          # bit-exact projection
        jax.effects_barrier()
        sqnr = col.sqnr_db()
        assert sqnr.shape == (2,)
        assert sqnr[1] == pytest.approx(120.0)      # capped, finite
        assert 20.0 < sqnr[0] < 40.0

    def test_tap_inactive_is_noop(self):
        assert not tap.stats_active() and not tap.error_active()
        tap.record_activation(jnp.int32(0), jnp.ones((2, 2)))  # no collector
        with tap.observing(StatsCollector(1, OBS)) as col:
            assert tap.stats_active()
            tap.record_activation(None, jnp.ones((2, 2)))      # no id
        jax.effects_barrier()
        assert not tap.stats_active()
        assert col.count[0] == 0


class TestEngineCalibration:
    def _cfg(self):
        from repro.configs.base import MFTechniqueConfig, ModelConfig
        return ModelConfig(
            name="serve-calib", family="lm", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
            dtype=jnp.float32,
            mf=MFTechniqueConfig(mode="cim_sim", cim=CimConfig(8, 8, 5, 31)))

    def test_engine_programs_calibrated_scales(self, tmp_path):
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        _, registry = attach_observer_ids(params)
        sxv = np.float32(0.0175)
        art = CalibrationArtifact(
            method="amax", x_bits=8,
            scales={name: np.full(shape or (), sxv, np.float32)
                    for name, (_, shape) in registry.entries.items()})
        path = str(tmp_path / "cal.json")
        art.save(path)
        eng = ServeEngine(params, cfg, slots=2, max_len=16,
                          calibration=path)     # loads from disk
        assert eng.programmed and eng.calibration is not None
        projs = iter_projections(eng._exec_params)
        assert projs
        name0, node0, _ = projs[0]
        np.testing.assert_allclose(np.asarray(node0["prog"].sx).reshape(-1),
                                   sxv, rtol=0)
        done = eng.run([Request(prompt=[1, 2], max_new_tokens=2)])
        assert len(done) == 1 and len(done[0].out) == 2

    def test_engine_rejects_mismatched_precision(self):
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        art = CalibrationArtifact(method="amax", x_bits=4, scales={})
        with pytest.raises(ValueError, match="x_bits"):
            ServeEngine(params, cfg, slots=1, max_len=8, calibration=art)

    def test_engine_rejects_foreign_artifact_names(self):
        # An artifact calibrated for a different model must not silently
        # degrade to the static default.
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        art = CalibrationArtifact(
            method="amax", x_bits=8,
            scales={"conv1": np.float32(0.02) * np.ones((), np.float32)})
        with pytest.raises(ValueError, match="does not match"):
            ServeEngine(params, cfg, slots=1, max_len=8, calibration=art)

    def test_engine_rejects_calibration_without_programming(self):
        from repro.models import transformer as T
        from repro.serve.engine import ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        art = CalibrationArtifact(method="amax", x_bits=8, scales={})
        with pytest.raises(ValueError, match="program"):
            ServeEngine(params, cfg, slots=1, max_len=8, program=False,
                        calibration=art)


class TestBatchedSlotReset:
    def _cfg(self):
        from repro.configs.base import MFTechniqueConfig, ModelConfig
        return ModelConfig(
            name="serve-batch", family="lm", n_layers=2, d_model=32,
            n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
            dtype=jnp.float32,
            mf=MFTechniqueConfig(enabled=False))

    def test_reset_slots_vector(self):
        from repro.models import transformer as T
        from repro.serve.engine import _reset_slots
        cfg = self._cfg()
        cache = T.lm_init_cache(cfg, 4, 8)
        cache = jax.tree.map(
            lambda v: v + 5 if v.dtype == jnp.int32 else v, cache)
        out = _reset_slots(cache, jnp.asarray([1, 3, 1, 1]))  # dup-safe
        np.testing.assert_array_equal(np.asarray(out["pos"]), [5, 0, 5, 0])

    def test_submit_many_admits_wave(self):
        from repro.models import transformer as T
        from repro.serve.engine import Request, ServeEngine
        cfg = self._cfg()
        params = T.lm_init(jax.random.PRNGKey(0), cfg)
        eng = ServeEngine(params, cfg, slots=3, max_len=16)
        reqs = [Request(prompt=[i + 1], max_new_tokens=2)
                for i in range(5)]
        n = eng.submit_many(reqs)
        assert n == 3 and eng.free_slots == []
        done = eng.run(reqs[n:])
        assert len(done) == 5
        assert all(len(r.out) == 2 and not r.timed_out for r in done)


class TestTrainedCalibration:
    """Trained checkpoints flow through calibrate_lm (ISSUE 5 satellite:
    ROADMAP "trained-model calibration")."""

    def _train_two_steps(self, cfg, tmp_path):
        from repro.configs.base import ParallelConfig, TrainConfig
        from repro.data.synthetic import DataConfig, lm_batch
        from repro.train import checkpoint as ckpt
        from repro.train import train_loop as TL
        tcfg = TrainConfig(lr=1e-3, warmup_steps=1, total_steps=4)
        state = TL.init_state(jax.random.PRNGKey(0), cfg, tcfg)
        # repro-lint: disable=R003 reason=one-shot test body wrapper
        step = jax.jit(TL.make_train_step(cfg, ParallelConfig(remat="none"),
                                          tcfg))
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                        global_batch=4, task="uniform")
        for i in range(2):
            batch = {k: jnp.asarray(v) for k, v in lm_batch(dc, i).items()}
            state, _ = step(state, batch)
        root = str(tmp_path / "ckpt")
        ckpt.save(root, 2, state)
        return root, state, tcfg

    def test_calibrate_lm_restores_trainstate_checkpoint(self, tmp_path):
        from repro.calib.report import calibrate_lm
        from repro.data.synthetic import DataConfig, lm_batch
        from repro.models import transformer as T
        cfg = _mk_cfg(mf=_cim_mf())
        root, state, tcfg = self._train_two_steps(cfg, tmp_path)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                        global_batch=4, task="uniform")
        cal = [{"tokens": jnp.asarray(lm_batch(dc, 10 + i)["tokens"])}
               for i in range(2)]
        template = T.lm_init(jax.random.PRNGKey(7), cfg)
        art = calibrate_lm(template, cfg, cal, method="amax",
                           checkpoint=root, train_cfg=tcfg)
        assert art.meta["checkpoint_step"] == 2
        # trained statistics differ from the template's random init
        base = calibrate_lm(template, cfg, cal, method="amax")
        assert any(not np.array_equal(art.scales[k], base.scales[k])
                   for k in art.scales)
        # and match calibrating directly on the trained params
        direct = calibrate_lm(state.params, cfg, cal, method="amax")
        for k in art.scales:
            np.testing.assert_array_equal(art.scales[k],
                                          direct.scales[k])

    def test_calibrate_lm_restores_bare_params_checkpoint(self, tmp_path):
        from repro.calib.report import calibrate_lm
        from repro.data.synthetic import DataConfig, lm_batch
        from repro.models import transformer as T
        from repro.train import checkpoint as ckpt
        cfg = _mk_cfg(mf=_cim_mf())
        root, state, _ = self._train_two_steps(cfg, tmp_path)
        root2 = str(tmp_path / "params-only")
        ckpt.save(root2, 3, state.params)
        dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=8,
                        global_batch=4, task="uniform")
        cal = [{"tokens": jnp.asarray(lm_batch(dc, 20)["tokens"])}]
        template = T.lm_init(jax.random.PRNGKey(7), cfg)
        art = calibrate_lm(template, cfg, cal, method="amax",
                           checkpoint=root2)
        assert art.meta["checkpoint_step"] == 3

    def test_missing_checkpoint_raises(self, tmp_path):
        from repro.calib.report import calibrate_lm
        from repro.models import transformer as T
        cfg = _mk_cfg(mf=_cim_mf())
        template = T.lm_init(jax.random.PRNGKey(7), cfg)
        with pytest.raises(FileNotFoundError):
            calibrate_lm(template, cfg, [], checkpoint=str(tmp_path / "x"))


def _cim_mf():
    from repro.configs.base import MFTechniqueConfig
    return MFTechniqueConfig(mode="cim_sim", cim=CimConfig(8, 8, 5, 31))
