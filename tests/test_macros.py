"""Macro zoo: registry dispatch, flavour parity, collaborative structure,
area re-budgeting, tiered re-trim, and the compiler's macro-aware Eq. 4.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler.cost import layer_cost, model_cost
from repro.compiler.schedule import compile_model
from repro.compiler.tiling import Fleet
from repro.core.cim import CimConfig, adc_codes, cim_mf_matmul
from repro.core.energy import unit_op_cycles, unit_op_energy_j
from repro.core.mapping import LayerStat, MappingPolicy
from repro.macros import (P8T, SAADC, CollaborativeDigitization, MacroModel,
                          as_macro, available, feasible_columns,
                          fleet_for_macro, get_macro,
                          reference_budget_units)
from repro.silicon.instance import (SiliconConfig, age,
                                    fleet_silicon, projection_silicon,
                                    recalibrate_comparators,
                                    retired_slots_mask, retrim_comparators,
                                    sample_fleet)
from repro.silicon.variability import calibrated_offset, retrim_offset

CIM = CimConfig(w_bits=8, x_bits=8, adc_bits=5, m_columns=31)
NOISY = SiliconConfig(cap_sigma=0.02, comparator_sigma_v=0.008,
                      thermal_sigma_v=0.001)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def test_registry_lists_builtin_flavours():
    names = available()
    assert {"saadc", "collaborative", "p8t"} <= set(names)
    assert names == tuple(sorted(names))


def test_registry_constructs_by_name_with_kwargs():
    m = get_macro("collaborative", group_size=8)
    assert isinstance(m, CollaborativeDigitization)
    assert m.group_size == 8


def test_registry_unknown_name_is_precise():
    with pytest.raises(ValueError, match=r"unknown macro model 'emram'.*"
                                         r"collaborative, p8t, saadc"):
        get_macro("emram")


def test_as_macro_coercions():
    assert isinstance(as_macro("p8t"), P8T)
    wrapped = as_macro(NOISY)
    assert isinstance(wrapped, SAADC) and wrapped.silicon == NOISY
    m = CollaborativeDigitization()
    assert as_macro(m) is m
    with pytest.raises(TypeError, match="MacroModel, SiliconConfig or "
                                        "registered macro name"):
        as_macro(42)


def test_register_requires_name():
    from repro.macros.registry import register

    class Nameless(MacroModel):
        name = ""

    with pytest.raises(ValueError, match="name"):
        register(Nameless)


# ---------------------------------------------------------------------------
# σ=0 bitwise parity for EVERY registered flavour
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", available())
def test_every_flavour_nominal_is_bitwise_nominal(name):
    model = get_macro(name).nominal()
    assert model.is_nominal
    k, n = 70, 9
    x = jax.random.normal(jax.random.PRNGKey(0), (4, k))
    w = jax.random.normal(jax.random.PRNGKey(1), (k, n))
    y0 = cim_mf_matmul(x, w, CIM)
    fleet = model.sample(jax.random.PRNGKey(2), 32, CIM.m_columns)
    sil = projection_silicon(fleet, model, k, n)
    y = cim_mf_matmul(x, w, CIM, silicon=sil)
    assert np.array_equal(np.asarray(y0), np.asarray(y))


# ---------------------------------------------------------------------------
# SA-ADC plug-in ≡ pre-registry silicon path at σ>0 (exact-code identity)
# ---------------------------------------------------------------------------

def test_saadc_sigma_pos_views_identical_to_raw_config():
    s = sample_fleet(jax.random.PRNGKey(3), 32, CIM.m_columns, NOISY)
    via_cfg = projection_silicon(s, NOISY, 70, 9)
    via_macro = projection_silicon(s, SAADC(silicon=NOISY), 70, 9)
    for a, b in zip(jax.tree.leaves(via_cfg), jax.tree.leaves(via_macro)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_saadc_fleet_sampling_identical_to_raw_config():
    fleet = Fleet(n_macros=16, cfg=CIM)
    a = fleet_silicon(fleet, NOISY)
    b = fleet_silicon(fleet, SAADC(silicon=NOISY))
    c = fleet_silicon(fleet, "saadc")   # default silicon ≠ NOISY: differs
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        assert np.array_equal(np.asarray(la), np.asarray(lb))
    assert not np.array_equal(np.asarray(a.offset_v), np.asarray(c.offset_v))


def test_saadc_sigma_pos_matmul_identical_to_raw_config():
    k, n = 3 * 31 + 5, 11
    x = jax.random.normal(jax.random.PRNGKey(4), (4, k))
    w = jax.random.normal(jax.random.PRNGKey(5), (k, n))
    s = sample_fleet(jax.random.PRNGKey(6), 48, CIM.m_columns, NOISY)
    y_cfg = cim_mf_matmul(x, w, CIM,
                          silicon=projection_silicon(s, NOISY, k, n))
    y_mac = cim_mf_matmul(x, w, CIM,
                          silicon=projection_silicon(
                              s, SAADC(silicon=NOISY), k, n))
    assert np.array_equal(np.asarray(y_cfg), np.asarray(y_mac))


def test_quantise_hook_matches_datapath_transfer_function():
    mav = jnp.linspace(-0.1, 1.1, 97)
    off = jnp.full_like(mav, 0.01)
    for name in available():
        model = get_macro(name)
        assert np.array_equal(np.asarray(model.quantise(mav, 5)),
                              np.asarray(adc_codes(mav, 5)))
        assert np.array_equal(np.asarray(model.quantise(mav, 5, off)),
                              np.asarray(adc_codes(mav, 5, off)))


# ---------------------------------------------------------------------------
# Collaborative digitization: sharing structure + coupling noise
# ---------------------------------------------------------------------------

def test_collaborative_same_key_same_shared_caps():
    m = CollaborativeDigitization(group_size=4, silicon=NOISY)
    f1 = m.sample(jax.random.PRNGKey(0), 10, 31)
    f2 = m.sample(jax.random.PRNGKey(0), 10, 31)
    for a, b in zip(jax.tree.leaves(f1), jax.tree.leaves(f2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    f3 = m.sample(jax.random.PRNGKey(1), 10, 31)
    assert not np.array_equal(np.asarray(f1.cap), np.asarray(f3.cap))


def test_collaborative_within_group_correlated_across_groups_not():
    g = 4
    m = CollaborativeDigitization(group_size=g, silicon=NOISY)
    f = m.sample(jax.random.PRNGKey(0), 11, 31)
    assert f.cap.shape == (11, 31)
    cap = np.asarray(f.cap)
    off = np.asarray(f.offset_v)
    for s in range(11):
        lead = (s // g) * g
        assert np.array_equal(cap[s], cap[lead])
        assert off[s] == off[lead]
    assert not np.array_equal(cap[0], cap[g])
    # drift directions share the group's instance too (correlated aging)
    dv = np.asarray(f.drift_dir_v)
    assert dv[0] == dv[g - 1] and dv[0] != dv[g]


def test_collaborative_group_matches_raw_sample_of_groups():
    """The shared instances ARE a raw SA-ADC fleet of n_groups slots."""
    m = CollaborativeDigitization(group_size=2, silicon=NOISY)
    f = m.sample(jax.random.PRNGKey(7), 8, 31)
    raw = sample_fleet(jax.random.PRNGKey(7), 4, 31, NOISY)
    assert np.array_equal(np.asarray(f.cap[::2]), np.asarray(raw.cap))
    assert np.array_equal(np.asarray(f.offset_v[::2]),
                          np.asarray(raw.offset_v))


def test_collaborative_coupling_noise_keyed_off_conversion_clock():
    from repro.core.cim import conversion_clock
    m = CollaborativeDigitization(group_size=4, coupling_sigma_v=0.002,
                                  silicon=NOISY)
    fleet = m.sample(jax.random.PRNGKey(0), 16, 31)
    sil = projection_silicon(fleet, m, 62, 4)
    assert sil.thermal_fs is not None
    # RMS: thermal ⊕ (G-1) coupling in quadrature, as full-scale fraction
    expect = np.sqrt(NOISY.thermal_sigma_v ** 2
                     + 3 * 0.002 ** 2) / NOISY.v_full_scale
    assert np.isclose(float(sil.thermal_fs), expect, rtol=1e-6)
    with conversion_clock(3):
        d1 = sil.dither((4, 4), 1)
    with conversion_clock(3):
        d2 = sil.dither((4, 4), 1)
    with conversion_clock(4):
        d3 = sil.dither((4, 4), 1)
    assert np.array_equal(np.asarray(d1), np.asarray(d2))
    assert not np.array_equal(np.asarray(d1), np.asarray(d3))


def test_collaborative_group1_no_coupling_is_saadc():
    m = CollaborativeDigitization(group_size=1, silicon=NOISY)
    raw = sample_fleet(jax.random.PRNGKey(2), 6, 31, NOISY)
    f = m.sample(jax.random.PRNGKey(2), 6, 31)
    assert np.array_equal(np.asarray(f.cap), np.asarray(raw.cap))
    fs, _ = m.conversion_pair()
    # thermal floor only — no neighbours to couple
    assert np.isclose(float(fs), NOISY.thermal_sigma_v / NOISY.v_full_scale)


def test_collaborative_validates_fields():
    with pytest.raises(ValueError, match="group_size"):
        CollaborativeDigitization(group_size=0)
    with pytest.raises(ValueError, match="coupling_sigma_v"):
        CollaborativeDigitization(coupling_sigma_v=-1.0)


# ---------------------------------------------------------------------------
# Area re-budgeting: ADC area traded for columns at fixed macro area
# ---------------------------------------------------------------------------

def test_collaborative_rebudget_widens_tiles():
    base = Fleet(n_macros=64, cfg=CIM)
    budget = reference_budget_units(CIM)
    for g, a in ((4, 5), (4, 6), (2, 6)):
        m = CollaborativeDigitization(group_size=g)
        f = fleet_for_macro(m, base, adc_bits=a)
        assert f.cfg.m_columns > CIM.m_columns, (g, a)
        assert m.half_area_units(f.cfg) <= budget
        assert f.macro is m
    # sanity: the SA-ADC re-budgets to itself
    f = fleet_for_macro(SAADC(), base)
    assert f.cfg.m_columns == CIM.m_columns


def test_p8t_rebudget_narrows_tiles():
    base = Fleet(n_macros=64, cfg=CIM)
    f = fleet_for_macro(P8T(), base)
    assert f.cfg.m_columns < CIM.m_columns
    assert P8T().half_area_units(f.cfg) <= reference_budget_units(CIM)


def test_feasible_columns_monotone_in_group_size():
    budget = reference_budget_units(CIM)
    ms = [feasible_columns(CollaborativeDigitization(group_size=g), 5,
                           budget_units=budget)
          for g in (1, 2, 4, 8)]
    assert ms == sorted(ms)
    assert ms[-1] > CIM.m_columns


def test_feasible_columns_rejects_impossible_envelope():
    with pytest.raises(ValueError, match="does not fit"):
        feasible_columns(SAADC(), 5, budget_units=90.0)


def test_compiler_prices_through_macro_hooks():
    stats = [LayerStat("proj", params=256 * 128, ops=2 * 256 * 128 * 4,
                       k=256, n=128)]
    base = Fleet(n_macros=256, cfg=CIM)
    collab = CollaborativeDigitization(group_size=4)
    fc = fleet_for_macro(collab, base, adc_bits=5)
    sched_b = compile_model(stats, base,
                            policy=MappingPolicy(threshold=0.0,
                                                 always_digital=()))
    sched_c = compile_model(stats, fc,
                            policy=MappingPolicy(threshold=0.0,
                                                 always_digital=()))
    # wider tiles ⇒ strictly fewer µArray tiles for the same projection
    assert sched_c.total_tiles < sched_b.total_tiles
    _, cost_b = model_cost(sched_b)
    _, cost_c = model_cost(sched_c)
    # per-unit-op pricing runs through the flavour's hooks
    lc = layer_cost(sched_c.layers[0], fc)
    assert lc.cycles == (sched_c.layers[0].macro_unit_ops
                         * collab.unit_op_cycles(fc.cfg))
    assert collab.unit_op_cycles(fc.cfg) > unit_op_cycles(fc.cfg)
    assert (collab.unit_op_energy_j(fc.cfg)
            > unit_op_energy_j(fc.cfg))
    assert cost_c.unit_ops < cost_b.unit_ops


def test_p8t_energy_cheaper_mav_same_adc():
    p = P8T(mav_energy_scale=0.6)
    assert p.unit_op_energy_j(CIM) < unit_op_energy_j(CIM)
    # only the MAV term scales: the difference is 40% of the MAV term
    from repro.core.energy import DEFAULT_MACRO
    mav = CIM.w_bits * CIM.m_columns * DEFAULT_MACRO.c_pl_v2_j
    assert np.isclose(unit_op_energy_j(CIM) - p.unit_op_energy_j(CIM),
                      0.4 * mav)


def test_p8t_sampling_tightens_cap_mismatch():
    p = P8T(dac_matching=0.5, silicon=NOISY)
    f = p.sample(jax.random.PRNGKey(0), 64, 31)
    raw = sample_fleet(jax.random.PRNGKey(0), 64, 31, NOISY)
    assert np.allclose(np.asarray(f.cap) - 1.0,
                       0.5 * (np.asarray(raw.cap) - 1.0), atol=1e-6)


# ---------------------------------------------------------------------------
# Tiered re-trim + retirement screening
# ---------------------------------------------------------------------------

def _aged_fleet(streams, n=256):
    # drift scale = 0.3 V/kstream x (streams/1000) on N(0,1) directions;
    # comparator sigma 8 mV => fine window ±30 mV, coarse window ±90 mV.
    # 100 streams (σ≈31 mV) leaves a healthy fine population, 150 streams
    # populates the coarse tier, 1000 streams (σ=300 mV) saturates most.
    scfg = dataclasses.replace(NOISY, thermal_sigma_v=0.0,
                               drift_sigma_v_per_kstream=0.3)
    return age(sample_fleet(jax.random.PRNGKey(11), n, 31, scfg),
               streams), scfg


def test_retrim_fine_tier_is_bitwise_the_single_tier_recal():
    sil, scfg = _aged_fleet(streams=100)
    single = recalibrate_comparators(sil, scfg)
    tiered, tiers = retrim_comparators(sil, scfg)
    tiers = np.asarray(tiers)
    fine = tiers == 0
    assert fine.any()
    assert np.array_equal(np.asarray(single.correction_v)[fine],
                          np.asarray(tiered.correction_v)[fine])


def test_retrim_coarse_tier_beats_saturated_fine_dac():
    from repro.silicon.instance import _drifted_offset_v
    sil, scfg = _aged_fleet(streams=150)
    single = recalibrate_comparators(sil, scfg)
    tiered, tiers = retrim_comparators(sil, scfg)
    tiers = np.asarray(tiers)
    coarse = tiers == 1
    assert coarse.any()
    raw = np.asarray(_drifted_offset_v(sil, scfg))
    res_single = np.abs(raw - np.asarray(single.correction_v))
    res_tiered = np.abs(raw - np.asarray(tiered.correction_v))
    # the saturated fine DAC leaves a strictly larger residue than the
    # re-biased coarse tier on every coarse-tier slot
    assert (res_tiered[coarse] < res_single[coarse]).all()


def test_retrim_tier2_flags_saturation_and_matches_mask():
    sil, scfg = _aged_fleet(streams=1000)
    _, tiers = retrim_comparators(sil, scfg)
    tiers = np.asarray(tiers)
    assert (tiers == 2).any()
    mask = np.asarray(retired_slots_mask(sil, scfg))
    assert np.array_equal(mask, tiers == 2)


def test_retrim_offset_tier_boundaries():
    scfg = SiliconConfig(comparator_sigma_v=0.015)  # fine range ±45 mV
    off = jnp.asarray([0.0, 0.040, 0.070, 0.500, -0.070, -0.500])
    residue, tier = retrim_offset(off, scfg)
    assert np.array_equal(np.asarray(tier), [0, 0, 1, 2, 1, 2])
    fine = np.asarray(calibrated_offset(off, scfg))
    r = np.asarray(residue)
    assert r[0] == fine[0] and r[1] == fine[1]
    # coarse LSB = 67.5 mV: the 70 mV slot trims to within half of that
    assert abs(r[2]) <= 0.03375 + 1e-9
    # saturated: residue is offset minus the clipped coarse DAC rail
    assert abs(r[3]) > 0.2


def test_retrim_noop_without_calibration():
    scfg = SiliconConfig(comparator_sigma_v=0.0)
    sil = sample_fleet(jax.random.PRNGKey(0), 8, 31, scfg)
    out, tiers = retrim_comparators(sil, scfg)
    assert out is sil
    assert not np.asarray(tiers).any()
    assert not np.asarray(retired_slots_mask(sil, scfg)).any()


# ---------------------------------------------------------------------------
# Monte-Carlo sweeps parameterise over the registry
# ---------------------------------------------------------------------------

def test_yield_curve_accepts_macro_models():
    from repro.silicon.montecarlo import projection_yield_curve
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 62))
    w = jax.random.normal(jax.random.PRNGKey(1), (62, 6))
    m = CollaborativeDigitization(
        group_size=4, coupling_sigma_v=0.0,
        silicon=SiliconConfig(comparator_sigma_v=0.0))
    # 0.2: well past the code-flip threshold of the lossless design
    # point (below it, mismatch cancels in the ratiometric conversion)
    pts = projection_yield_curve(jax.random.PRNGKey(2), x, w, CIM, m,
                                 sigmas=(0.0, 0.2), n_seeds=4)
    assert pts[0].mean_sqnr_db > pts[1].mean_sqnr_db
    assert pts[0].yield_frac == 1.0


def test_yield_curve_macro_vs_config_identical_for_saadc():
    from repro.silicon.montecarlo import projection_sqnr_samples
    x = jax.random.normal(jax.random.PRNGKey(0), (4, 62))
    w = jax.random.normal(jax.random.PRNGKey(1), (62, 6))
    base = SiliconConfig(comparator_sigma_v=0.0, cap_sigma=0.05)
    s_cfg = projection_sqnr_samples(jax.random.PRNGKey(2), x, w, CIM,
                                    base, 4)
    s_mac = projection_sqnr_samples(jax.random.PRNGKey(2), x, w, CIM,
                                    SAADC(silicon=base), 4)
    assert np.array_equal(np.asarray(s_cfg), np.asarray(s_mac))


# ---------------------------------------------------------------------------
# Engine integration (slow: builds serving engines)
# ---------------------------------------------------------------------------

def _engine_cfg():
    from repro.configs.base import MFTechniqueConfig, ModelConfig
    return ModelConfig(
        name="macro-tiny", family="lm", n_layers=2, d_model=32,
        n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=64,
        dtype=jnp.float32,
        mf=MFTechniqueConfig(mode="cim_sim", cim=CimConfig(4, 4, 5, 31)))


@pytest.mark.slow
def test_engine_accepts_macro_by_name_and_nominal_parity():
    from repro.models import transformer as T
    from repro.serve.engine import Request, ServeEngine
    cfg = _engine_cfg()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    fleet = Fleet(n_macros=4096, cfg=cfg.mf.cim)

    def serve(silicon):
        eng = ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet,
                          batched_prefill=False, silicon=silicon)
        return eng, [r.out for r in eng.run(
            [Request(prompt=[1, 2, 3], max_new_tokens=4)
             for _ in range(2)])]

    _, ref = serve(None)
    # a nominal macro of ANY flavour serves the silicon-free tokens
    _, toks = serve(CollaborativeDigitization(group_size=4).nominal())
    assert toks == ref
    # a registered name resolves (σ>0 default silicon: tokens may differ,
    # but the engine must construct, serve, and expose the macro)
    eng, toks = serve("saadc")
    assert isinstance(eng.macro, SAADC)
    assert len(toks) == 2 and all(len(t) == 4 for t in toks)


@pytest.mark.slow
def test_engine_rejects_unknown_macro_name_precisely():
    from repro.models import transformer as T
    from repro.serve.engine import ServeEngine
    cfg = _engine_cfg()
    params = T.lm_init(jax.random.PRNGKey(0), cfg)
    fleet = Fleet(n_macros=4096, cfg=cfg.mf.cim)
    with pytest.raises(ValueError, match="unknown macro model 'emram'"):
        ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet,
                    batched_prefill=False, silicon="emram")
    with pytest.raises(TypeError, match="MacroModel, SiliconConfig or "):
        ServeEngine(params, cfg, slots=2, max_len=16, fleet=fleet,
                    batched_prefill=False, silicon=3.14)
