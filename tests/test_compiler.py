"""Macro-compiler tests: tiling invariants, schedule/cost identities,
fleet-aware mapping, and bit-exact tiled execution."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compiler import (Fleet, compile_model, compiled_matmul,
                            layer_table, lm_layer_stats, model_cost,
                            plan_tiling, rollup_summary,
                            schedule_layer, verify_bit_exact)
from repro.core import (CimConfig, ExecMode, FleetMappingPolicy, LayerStat,
                        cim_mf_matmul, unit_op_energy_j)
from repro.silicon.variability import sample_cap_weights, VariabilityConfig
from repro.models.convnets import cifar_layer_stats, lenet_layer_stats

CFG62 = CimConfig(8, 8, 5, 31)
CFG30 = CimConfig(8, 8, 4, 15)


class TestTiling:
    def test_tile_counts_and_padding(self):
        plan = plan_tiling(70, 9, CFG62)
        assert plan.n_chunks == 3 and plan.k_padded == 93
        assert plan.pad_k == 23 and plan.n_tiles == 27
        assert plan.waste_fraction == pytest.approx(23 / 93)

    def test_divisible_k_has_no_waste(self):
        plan = plan_tiling(62, 4, CFG62)
        assert plan.pad_k == 0 and plan.waste_fraction == 0.0

    def test_k_slices_chunk_aligned(self):
        plan = plan_tiling(200, 24, CFG30, tile_k_chunks=3, tile_n=7)
        for (k0, k1) in plan.k_slices[:-1]:
            assert (k1 - k0) % CFG30.m_columns == 0
        assert plan.k_slices[0][0] == 0 and plan.k_slices[-1][1] == 200
        assert plan.n_slices[-1][1] == 24

    def test_fleet_capacity(self):
        fleet = Fleet(n_macros=8, cfg=CFG62)
        assert fleet.tile_slots == 16
        assert fleet.tile_weight_bits == 31 * 8
        assert fleet.weight_capacity_bits == 16 * 31 * 8


class TestSchedule:
    def test_resident_layer_single_round(self):
        fleet = Fleet(n_macros=16, cfg=CFG62)      # 32 slots
        s = schedule_layer(fleet.plan(62, 16), fleet, calls=10)
        assert s.rounds == 1 and s.fits_resident   # 32 tiles fit
        assert s.unit_ops == 2 * 16 * 10
        # 32 tiles over 16 macros -> 2 serial tiles/macro x 10 calls
        assert s.macro_unit_ops == 20

    def test_oversized_layer_rounds(self):
        fleet = Fleet(n_macros=2, cfg=CFG62)   # 4 slots
        s = schedule_layer(fleet.plan(31, 10), fleet, calls=3)
        assert s.rounds == math.ceil(10 / 4) == 3
        assert s.unit_ops == 10 * 3
        # rounds of 4,4,2 tiles over 2 macros: (2+2+1) passes x 3 calls
        assert s.macro_unit_ops == 15
        assert s.reload_bits == 10 * fleet.tile_weight_bits

    def test_more_macros_reduce_critical_path(self):
        plan = plan_tiling(310, 64, CFG62)
        crits = [schedule_layer(plan, Fleet(n_macros=n, cfg=CFG62),
                                calls=4).macro_unit_ops
                 for n in (4, 16, 64, 256)]
        assert crits == sorted(crits, reverse=True)
        assert crits[-1] < crits[0]

    def test_pinned_model_has_no_reloads(self):
        fleet = Fleet(n_macros=64, cfg=CFG62)    # 128 slots, lenet needs 86
        ms = compile_model(lenet_layer_stats(), fleet,
                           policy=fleet.mapping_policy(threshold=1.0))
        assert ms.pinned
        assert all(s.reload_bits == 0 for s in ms.layers)
        swapped = compile_model(lenet_layer_stats(),
                                Fleet(n_macros=64, cfg=CFG62,
                                      weight_stationary=False))
        assert not swapped.pinned
        assert all(s.reload_bits > 0 for s in swapped.layers)


class TestCost:
    def test_energy_identity_unit_ops_times_unit_energy(self):
        """Acceptance: schedule unit-op total x unit_op_energy_j == roll-up."""
        fleet = Fleet(n_macros=32, cfg=CFG62, weight_stationary=False)
        ms = compile_model(cifar_layer_stats(), fleet)
        assert ms.layers, "no CIM layers mapped"
        costs, total = model_cost(ms)
        e_unit = unit_op_energy_j(CFG62)
        assert total.unit_ops == sum(s.unit_ops for s in ms.layers)
        assert total.compute_energy_j == total.unit_ops * e_unit
        for s, c in zip(ms.layers, costs):
            assert c.compute_energy_j == s.unit_ops * e_unit

    def test_utilization_bounded_and_tops_below_peak(self):
        from repro.core import tops_per_watt
        fleet = Fleet(n_macros=16, cfg=CFG62, weight_stationary=False)
        ms = compile_model(cifar_layer_stats(), fleet)
        costs, total = model_cost(ms)
        for c in costs:
            assert 0.0 < c.utilization <= 1.0
            # padding + reload overheads keep layers at/below Table II peak
            assert c.tops_per_w <= tops_per_watt(CFG62) + 1e-9
        assert 0.0 < total.utilization <= 1.0
        assert total.latency_s > 0 and total.energy_j > 0

    def test_rollup_sums_layers(self):
        fleet = Fleet(n_macros=16, cfg=CFG30, weight_stationary=False)
        ms = compile_model(lenet_layer_stats(), fleet)
        costs, total = model_cost(ms)
        assert total.mac_ops == sum(c.mac_ops for c in costs)
        assert total.latency_s == pytest.approx(
            sum(c.latency_s for c in costs))
        assert total.reload_energy_j == pytest.approx(
            sum(c.reload_energy_j for c in costs))

    def test_report_renders(self):
        fleet = Fleet(n_macros=16, cfg=CFG62, weight_stationary=False)
        ms = compile_model(lenet_layer_stats(), fleet)
        costs, total = model_cost(ms)
        table = layer_table(ms, costs)
        assert "conv1" in table and "TOPS/W" in table
        assert "utilization" in rollup_summary(ms, total)


class TestFleetMapping:
    BIG = LayerStat("mid_proj", 1024 * 1024, 2 * 1024 * 1024 * 64,
                    k=1024, n=1024)

    def test_capacity_gates_cim(self):
        small = Fleet(n_macros=8, cfg=CFG62)
        big = Fleet(n_macros=32768, cfg=CFG62)
        assert small.mapping_policy().assign(self.BIG) == ExecMode.REGULAR
        assert big.mapping_policy().assign(self.BIG) == ExecMode.MF

    def test_swap_fleet_lifts_capacity_gate(self):
        swap = Fleet(n_macros=8, cfg=CFG62, weight_stationary=False)
        assert swap.mapping_policy().assign(self.BIG) == ExecMode.MF

    def test_threshold_and_name_rules_still_apply(self):
        pol = Fleet(n_macros=32768, cfg=CFG62).mapping_policy()
        head = LayerStat("lm_head", 10_000, 2 * 10_000 * 100, k=100, n=100)
        cold = LayerStat("proj", 10_000, 10_000, k=100, n=100)
        assert pol.assign(head) == ExecMode.REGULAR   # always-digital name
        assert pol.assign(cold) == ExecMode.REGULAR   # ops/param below 2.0
        warm = LayerStat("proj", 10_000, 2 * 10_000 * 100, k=100, n=100)
        assert pol.assign(warm) == ExecMode.MF

    def test_unshaped_layer_uses_param_estimate(self):
        pol = FleetMappingPolicy(capacity_tiles=16, m_columns=31)
        fat = LayerStat("proj", 31 * 1000, 2 * 31 * 1000 * 50)  # ~1000 tiles
        assert pol.assign(fat) == ExecMode.REGULAR

    def test_compile_model_lm_frontend(self):
        from repro.configs.registry import get_config
        cfg = get_config("qwen3-0.6b", smoke=True)
        stats = lm_layer_stats(cfg, tokens=32)
        fleet = Fleet(n_macros=512, cfg=CFG62, weight_stationary=False)
        ms = compile_model(stats, fleet)
        assert len(ms.layers) == 4 * cfg.n_layers      # qkv/out/up/down
        names = {s.name for s in ms.digital}
        assert "embed" in names and "lm_head" in names


class TestBitExactExecution:
    """Acceptance: tiled execution == monolithic simulator, bit for bit."""

    CASES = [
        # (K, N, cfg, tile_k_chunks, tile_n) — incl. non-divisible shapes
        (70, 9, CFG62, 1, 4),
        (100, 17, CFG30, 3, 5),
        (124, 33, CFG62, 2, 32),
        (45, 24, CimConfig(8, 8, 3, 15), 7, 7),   # lossy ADC pairing
        (31, 1, CFG62, 1, 1),                      # single-tile degenerate
    ]

    @pytest.mark.parametrize("k,n,cfg,tkc,tn", CASES)
    def test_bit_exact(self, k, n, cfg, tkc, tn):
        x = jax.random.normal(jax.random.PRNGKey(k), (5, k))
        w = jax.random.normal(jax.random.PRNGKey(n), (k, n))
        plan = plan_tiling(k, n, cfg, tile_k_chunks=tkc, tile_n=tn)
        tiled = compiled_matmul(x, w, plan, cfg)
        mono = cim_mf_matmul(x, w, cfg)
        np.testing.assert_array_equal(np.asarray(tiled), np.asarray(mono))

    def test_bit_exact_batched_input(self):
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 3, 70))
        w = jax.random.normal(jax.random.PRNGKey(1), (70, 9))
        plan = plan_tiling(70, 9, CFG62, tile_k_chunks=1, tile_n=4)
        tiled = compiled_matmul(x, w, plan, CFG62)
        assert tiled.shape == (2, 3, 9)
        np.testing.assert_array_equal(np.asarray(tiled),
                                      np.asarray(cim_mf_matmul(x, w, CFG62)))

    def test_bit_exact_with_variability(self):
        k, n = 93, 6
        x = jax.random.normal(jax.random.PRNGKey(2), (4, k))
        w = jax.random.normal(jax.random.PRNGKey(3), (k, n))
        caps = sample_cap_weights(jax.random.PRNGKey(4), k,
                                  VariabilityConfig(cap_sigma=0.1))
        plan = plan_tiling(k, n, CFG62, tile_k_chunks=1, tile_n=2)
        assert verify_bit_exact(x, w, plan, CFG62, cap_weights=caps,
                                comparator_offset=jnp.float32(0.01))

    def test_plan_operand_mismatch_raises(self):
        plan = plan_tiling(70, 9, CFG62)
        x = jax.random.normal(jax.random.PRNGKey(0), (2, 62))
        w = jax.random.normal(jax.random.PRNGKey(1), (62, 9))
        with pytest.raises(ValueError):
            compiled_matmul(x, w, plan, CFG62)
        with pytest.raises(ValueError):
            compiled_matmul(
                jax.random.normal(jax.random.PRNGKey(0), (2, 70)),
                jax.random.normal(jax.random.PRNGKey(1), (70, 9)),
                plan, CFG30)
